//! The pure coordinator state machine.
//!
//! `CoordinatorCore` owns everything Algorithm 1 needs on the *server*
//! side — the layer-wise `Schedule`, the Eq. 9 `CommLedger`, the
//! participation `ClientSampler`, the global model, and the round/loss
//! bookkeeping — and nothing else.  It consumes protocol events
//! (block losses, layer updates) and emits protocol commands
//! (`RoundAssignment`s, `SyncDecision`s).  It performs **no model
//! compute and no I/O**: local training happens in participants, and
//! evaluation is injected by the driver (`Coordinator::run`), so the same
//! core drives the in-proc transport, the multi-process transport, and —
//! because every input/output is a serializable message — any future
//! network transport, with bit-identical results.
//!
//! The only numeric kernel the core runs is the server's own weighted
//! aggregation (`aggregation::aggregate_native`), which *is* the
//! protocol's decision function: it produces u_l and the discrepancy d_l
//! that Algorithm 2 feeds on.  Call order matches the historical
//! single-process coordinator exactly (tensors within a group, groups
//! within a block, clients in active order), which is what keeps the
//! refactor bit-identical to the seed implementation.

use anyhow::{Context, Result};

use crate::aggregation::{RobustSpec, Schedule};
use crate::clients::ClientSampler;
use crate::comm::CommLedger;
use crate::config::{Algorithm, RunConfig};
use crate::data::{partition_for, Partition};
use crate::metrics::{CurvePoint, RunMetrics};
use crate::registry::ClientRegistry;
use crate::runtime::{GroupInfo, HostTensor};

use super::messages::{
    cfg_wire_bytes, AlgoState, ControlUpdate, LayerUpdate, Message, RoundAssignment, SyncDecision,
};
use super::wire::{Dec, Enc, WIRE_VERSION};

/// Optional fused-aggregation hook: (stacked rows [m, dim], weights, dim)
/// -> (u, discrepancy).  The driver wires this to the backend's Pallas
/// kernel when `--backend xla` forces it; the core itself stays
/// compute-agnostic.
pub type FusedAgg<'a> = dyn FnMut(&[f32], &[f32], usize) -> Result<(Vec<f32>, f32)> + 'a;

/// What `end_block` tells the driver about the block that just finished.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockOutcome {
    /// More blocks remain in the current round.
    MidRound,
    /// The block closed a round; the driver may need to evaluate before
    /// `complete_round` records the curve point.
    RoundComplete { round: usize, total_rounds: usize, train_loss: f64, eval_due: bool },
}

/// Where one remote peer stands in the join handshake.
///
/// The socket join flow (participant connects *to* the coordinator, so the
/// participant speaks first — the stdio transport's flow reversed):
///
/// ```text
///   AwaitJoin  --Hello{version}-------------------> send Configure
///   AwaitReady --Hello{version, shard_id, len}----> Ready
///   Ready      --Heartbeat{nonce}-----------------> (echo of our ping)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinPhase {
    /// Waiting for the peer's version Hello (its first frame after
    /// connecting; shard fields are zero — it has no assignment yet).
    AwaitJoin,
    /// Configure sent; waiting for the readiness Hello that confirms the
    /// assigned shard (the peer builds its backend in between, which can
    /// be slow — the transport heartbeats other peers meanwhile).
    AwaitReady,
    /// Handshake complete; the peer participates in the block loop.
    Ready,
}

/// What the transport must do after feeding a message to [`JoinHandshake`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinAction {
    /// Send this peer its `Configure` (shard assignment + run config).
    SendConfigure,
    /// The peer just became ready.
    Ready,
    /// The peer echoed a liveness ping with this nonce.
    Pong(u64),
}

/// Pure per-peer state machine for the socket join handshake.  Owns no
/// I/O: the transport reads frames, feeds them here, and performs the
/// returned [`JoinAction`].  Violations (wrong message for the phase,
/// version or shard mismatch) are errors the transport turns into a
/// connection drop.
///
/// The `Hello` version gate is deliberately *exact* equality even though
/// the frame layer accepts `MIN_WIRE_VERSION..=WIRE_VERSION`: the frame
/// range is what lets a decoder recognize older frames at all (so the
/// mismatch error here can be decoded and reported instead of looking
/// like corruption), while federation itself requires same-build peers —
/// bit-identical numerics across transports is the contract, and that is
/// only audited per build.
pub struct JoinHandshake {
    shard_id: usize,
    shard_len: usize,
    phase: JoinPhase,
}

impl JoinHandshake {
    /// Track the handshake for the peer that will own shard `shard_id`
    /// with `shard_len` clients.
    pub fn new(shard_id: usize, shard_len: usize) -> JoinHandshake {
        JoinHandshake { shard_id, shard_len, phase: JoinPhase::AwaitJoin }
    }

    pub fn phase(&self) -> JoinPhase {
        self.phase
    }

    pub fn is_ready(&self) -> bool {
        self.phase == JoinPhase::Ready
    }

    /// The shard this handshake was opened for.
    pub fn shard(&self) -> usize {
        self.shard_id
    }

    /// Feed one incoming message; returns the transport's next action or
    /// a protocol violation.
    pub fn on_message(&mut self, m: &Message) -> Result<JoinAction> {
        match (self.phase, m) {
            (JoinPhase::AwaitJoin, Message::Hello(h)) => {
                anyhow::ensure!(
                    h.version == WIRE_VERSION,
                    "participant speaks protocol v{}, coordinator v{WIRE_VERSION}",
                    h.version
                );
                self.phase = JoinPhase::AwaitReady;
                Ok(JoinAction::SendConfigure)
            }
            (JoinPhase::AwaitReady, Message::Hello(h)) => {
                anyhow::ensure!(
                    h.version == WIRE_VERSION,
                    "participant speaks protocol v{}, coordinator v{WIRE_VERSION}",
                    h.version
                );
                anyhow::ensure!(
                    h.worker_id == self.shard_id,
                    "participant confirmed shard {}, assigned {}",
                    h.worker_id,
                    self.shard_id
                );
                anyhow::ensure!(
                    h.shard_len == self.shard_len,
                    "participant claims {} clients, shard {} holds {}",
                    h.shard_len,
                    self.shard_id,
                    self.shard_len
                );
                self.phase = JoinPhase::Ready;
                Ok(JoinAction::Ready)
            }
            (JoinPhase::Ready, Message::Heartbeat(h)) => Ok(JoinAction::Pong(h.nonce)),
            (phase, other) => anyhow::bail!(
                "unexpected {} from shard {} during join handshake ({phase:?})",
                other.kind_name(),
                self.shard_id
            ),
        }
    }
}

/// Lifecycle of one peer across the whole run — the membership layer on
/// top of [`JoinHandshake`]:
///
/// ```text
///   Joining --handshake done--> Ready --admitted at a round
///                                       boundary--> Working
///   Working --disconnect / timeout / Abort--> Departed
///   Departed --(a fresh connection claims the vacant shard; a new
///               PeerSession starts at Joining)
/// ```
///
/// `Ready` peers are parked until the next `new_round` assignment: a shard
/// can only (re)enter between rounds, because mid-round client state
/// cannot be reconstructed from the wire protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerPhase {
    /// Connected, walking the join handshake.
    Joining,
    /// Handshake complete; waiting for the next round boundary.
    Ready,
    /// Admitted to the block loop; receives assignments and decisions.
    Working,
    /// Gone (disconnect, I/O timeout, or explicit Abort); its shard is
    /// vacant and may be claimed by a later connection.
    Departed,
}

/// Per-peer session state machine: a [`JoinHandshake`] plus the
/// Working/Departed membership phases the elastic transport tracks for
/// the lifetime of the connection.  Pure — no I/O.
pub struct PeerSession {
    handshake: JoinHandshake,
    phase: PeerPhase,
}

impl PeerSession {
    /// Open a session for a peer claiming shard `shard_id` (`shard_len`
    /// clients).
    pub fn new(shard_id: usize, shard_len: usize) -> PeerSession {
        let handshake = JoinHandshake::new(shard_id, shard_len);
        PeerSession { handshake, phase: PeerPhase::Joining }
    }

    pub fn phase(&self) -> PeerPhase {
        self.phase
    }

    pub fn shard(&self) -> usize {
        self.handshake.shard()
    }

    pub fn is_working(&self) -> bool {
        self.phase == PeerPhase::Working
    }

    /// Feed one incoming message while Joining; delegates to the
    /// handshake and flips to Ready when it completes.  Heartbeat echoes
    /// keep flowing through after that.
    pub fn on_message(&mut self, m: &Message) -> Result<JoinAction> {
        anyhow::ensure!(
            self.phase == PeerPhase::Joining || self.phase == PeerPhase::Ready,
            "shard {} got a handshake message in phase {:?}",
            self.shard(),
            self.phase
        );
        let action = self.handshake.on_message(m)?;
        if action == JoinAction::Ready {
            self.phase = PeerPhase::Ready;
        }
        Ok(action)
    }

    /// Admit a Ready peer into the block loop (round boundaries only —
    /// the transport enforces *when*, this enforces *from where*).
    pub fn promote(&mut self) -> Result<()> {
        anyhow::ensure!(
            self.phase == PeerPhase::Ready,
            "shard {} promoted from {:?}, expected Ready",
            self.shard(),
            self.phase
        );
        self.phase = PeerPhase::Working;
        Ok(())
    }

    /// Mark the peer gone.  Idempotent — a socket error and a timeout may
    /// both report the same departure.
    pub fn depart(&mut self) {
        self.phase = PeerPhase::Departed;
    }
}

pub struct CoordinatorCore {
    cfg: RunConfig,
    pub schedule: Schedule,
    pub ledger: CommLedger,
    pub sampler: ClientSampler,
    pub partition: Partition,
    /// The persistent client roster: per-client participation and byte
    /// state behind the registry store seam (in-memory by default).
    pub registry: ClientRegistry,
    /// The authoritative global model.
    pub global: Vec<HostTensor>,
    /// SCAFFOLD server control variate `c` — the authoritative copy; the
    /// per-round fold broadcasts it to participants as a `ControlUpdate`.
    /// Lazily zero-initialized on the first scaffold fold.
    server_control: Option<Vec<HostTensor>>,
    /// Learning-curve points recorded at round boundaries.
    pub curve: Vec<CurvePoint>,
    groups: Vec<GroupInfo>,
    active: Vec<usize>,
    weights: Vec<f32>,
    block: usize,
    blocks: usize,
    gap: usize,
    round_len: usize,
    round: usize,
    total_rounds: usize,
    round_loss_sum: f64,
    round_loss_n: usize,
    pending_new_round: bool,
    stack_scratch: Vec<f32>,
    /// Parsed `--aggregator` spec; `mean` keeps the zero-copy fold.
    robust: RobustSpec,
}

impl CoordinatorCore {
    /// `groups` is the manifest's aggregation layout; `global` the
    /// initialized model.  `cfg` must already be validated.
    pub fn new(cfg: &RunConfig, groups: Vec<GroupInfo>, global: Vec<HostTensor>) -> Self {
        let gap = cfg.policy.base_interval();
        let round_len = cfg.policy.round_len();
        let dims: Vec<usize> = groups.iter().map(|g| g.dim).collect();
        let names: Vec<(String, usize)> =
            groups.iter().map(|g| (g.name.clone(), g.dim)).collect();
        CoordinatorCore {
            schedule: Schedule::new(cfg.policy.clone(), dims),
            // per-participant counters fold by round-robin shard: one slot
            // in-proc, `workers` slots for the process/TCP transports —
            // identical tables for every transport with the same count
            ledger: CommLedger::with_shards(&names, cfg.workers.max(1)),
            sampler: ClientSampler::new(cfg.n_clients, cfg.active_ratio, cfg.seed),
            partition: partition_for(cfg),
            registry: ClientRegistry::in_memory(cfg.n_clients, cfg.seed),
            global,
            server_control: None,
            curve: Vec::new(),
            groups,
            active: Vec::new(),
            weights: Vec::new(),
            block: 0,
            blocks: cfg.iterations / gap,
            gap,
            round_len,
            round: 0,
            total_rounds: cfg.iterations / round_len,
            round_loss_sum: 0.0,
            round_loss_n: 0,
            pending_new_round: true,
            stack_scratch: Vec::new(),
            robust: RobustSpec::parse(&cfg.aggregator)
                .expect("cfg validated: --aggregator spec parses"),
            cfg: cfg.clone(),
        }
    }

    /// Active clients of the current round (sorted ids).
    pub fn active(&self) -> &[usize] {
        &self.active
    }

    /// Aggregation weights parallel to `active()`.
    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    pub fn total_rounds(&self) -> usize {
        self.total_rounds
    }

    /// Learning rate at a given round (linear warmup, as in the paper).
    pub fn lr_at(&self, round: usize) -> f32 {
        if self.cfg.warmup_rounds == 0 || round >= self.cfg.warmup_rounds {
            self.cfg.lr
        } else {
            self.cfg.lr * (round + 1) as f32 / self.cfg.warmup_rounds as f32
        }
    }

    /// Start the next training block: samples a fresh active set at round
    /// boundaries and emits the assignment.  `None` once all blocks ran.
    pub fn begin_block(&mut self) -> Option<RoundAssignment> {
        if self.block >= self.blocks {
            return None;
        }
        if self.pending_new_round {
            self.active = self.sampler.sample();
            self.weights = self.partition.active_weights(&self.active);
        }
        let new_round = std::mem::take(&mut self.pending_new_round);
        self.block += 1;
        let k = self.block * self.gap;
        let due_groups = match self.cfg.algorithm {
            // FedNova replaces group-wise averaging with a full-model
            // normalized delta at round boundaries; no layer uplinks.
            Algorithm::Nova => Vec::new(),
            _ => self.schedule.due_groups(k),
        };
        Some(RoundAssignment {
            k,
            round: self.round,
            gap: self.gap,
            lr: self.lr_at(self.round),
            new_round,
            active: self.active.clone(),
            due_groups,
        })
    }

    /// Absorb the block's per-client mean losses (active order; NaN =
    /// budget-exhausted client, skipped like the historical loop).
    pub fn record_losses(&mut self, losses: &[f64]) {
        for &loss in losses {
            if loss.is_finite() {
                self.round_loss_sum += loss;
                self.round_loss_n += 1;
            }
        }
    }

    /// Aggregate the block's layer updates: for every due group, order the
    /// client rows by the active list, average them into the global model,
    /// observe the discrepancy for Algorithm 2, charge the ledger, and
    /// emit one `SyncDecision` per group.  `fused` (when given, and when
    /// the payloads are dense) routes the weighted average through an
    /// external fused kernel instead of `aggregate_native`.
    pub fn apply_updates(
        &mut self,
        a: &RoundAssignment,
        updates: &[LayerUpdate],
        fused: Option<&mut FusedAgg<'_>>,
    ) -> Result<Vec<SyncDecision>> {
        self.apply_updates_quorum(a, updates, &[], fused)
    }

    /// Quorum-mode variant of [`apply_updates`](Self::apply_updates):
    /// `absent` names active clients whose shard departed mid-block and
    /// sent nothing.  Survivors are the active list minus `absent`, kept
    /// in active order, and their weights are renormalized over the
    /// surviving subset — so the result depends only on *which* set
    /// survived, never on arrival order.  With `absent` empty this is
    /// byte-identical to the full-roster path.
    pub fn apply_updates_quorum(
        &mut self,
        a: &RoundAssignment,
        updates: &[LayerUpdate],
        absent: &[usize],
        mut fused: Option<&mut FusedAgg<'_>>,
    ) -> Result<Vec<SyncDecision>> {
        if a.due_groups.is_empty() {
            anyhow::ensure!(
                updates.is_empty(),
                "got {} layer updates but no group was due at k={}",
                updates.len(),
                a.k
            );
            return Ok(Vec::new());
        }
        let survivors: Vec<usize> =
            a.active.iter().copied().filter(|c| !absent.contains(c)).collect();
        let m = survivors.len();
        anyhow::ensure!(m > 0, "no surviving clients to aggregate at k={}", a.k);
        // Every update must belong to a due group: each due group consumes
        // exactly m updates below, so a count mismatch means some frame
        // carried a non-due group (or a duplicate) — reject it rather than
        // silently dropping it.
        anyhow::ensure!(
            updates.len() == a.due_groups.len() * m,
            "expected {} layer updates ({} due groups x {m} reporting clients) at k={}, got {}",
            a.due_groups.len() * m,
            a.due_groups.len(),
            a.k,
            updates.len()
        );
        // Full roster reuses the round's cached weights bit-for-bit; a
        // partial commit renormalizes over the survivors.
        let weights = if absent.is_empty() {
            self.weights.clone()
        } else {
            self.partition.active_weights(&survivors)
        };
        self.ledger.record_round();
        // roster accounting accumulators (registry writes go through the
        // store seam once per survivor, after the group loop)
        let mut reg_uplink = vec![0u64; m];
        let mut reg_downlink = 0u64;
        let mut decisions = Vec::with_capacity(a.due_groups.len());
        for &g in &a.due_groups {
            let group = &self.groups[g];
            // Collect this group's updates in survivor (active) order —
            // arrival order (worker interleaving) must not influence the
            // result.
            let mut per_client: Vec<Option<&LayerUpdate>> = vec![None; m];
            for u in updates.iter().filter(|u| u.group == g) {
                let slot = survivors
                    .iter()
                    .position(|&ci| ci == u.client)
                    .with_context(|| format!("update from inactive client {}", u.client))?;
                anyhow::ensure!(
                    per_client[slot].is_none(),
                    "duplicate update for group {g} client {}",
                    u.client
                );
                anyhow::ensure!(u.k == a.k, "update k={} for block k={}", u.k, a.k);
                anyhow::ensure!(
                    u.tensors.len() == group.params.len(),
                    "group {g} expects {} tensors, got {}",
                    group.params.len(),
                    u.tensors.len()
                );
                per_client[slot] = Some(u);
            }
            let per_client: Vec<&LayerUpdate> = per_client
                .into_iter()
                .enumerate()
                .map(|(i, u)| {
                    u.with_context(|| {
                        format!("missing update for group {g} from active client {}", survivors[i])
                    })
                })
                .collect::<Result<_>>()?;

            // one pass: per-update nominal size feeds both the group total
            // and the per-participant fold
            let mut uplink_total = 0usize;
            for (slot, u) in per_client.iter().enumerate() {
                let nominal: usize = u.tensors.iter().map(|p| p.nominal_bytes()).sum();
                uplink_total += nominal;
                reg_uplink[slot] += nominal as u64;
                self.ledger.record_uplink(u.client, nominal);
            }

            let all_dense =
                per_client.iter().all(|u| u.tensors.iter().all(|p| p.as_dense().is_some()));
            let disc = if self.robust.is_mean() {
                match fused.as_mut() {
                    Some(f) if all_dense => {
                        self.aggregate_group_fused(g, &per_client, &weights, f)?
                    }
                    _ => self.aggregate_group_native(g, &per_client, &weights)?,
                }
            } else {
                self.aggregate_group_robust(g, &per_client, &weights, &survivors)?
            };

            self.schedule.observe(g, disc);
            self.ledger.record_sync_bytes(g, m, uplink_total / m.max(1));
            // dense group params broadcast to every surviving client
            let dense_down = self.groups[g].dim * 4;
            reg_downlink += dense_down as u64;
            for &c in &survivors {
                self.ledger.record_downlink(c, dense_down);
            }
            // pFedLA-style personalization: refresh each survivor's layer
            // mixing weight from its agreement with the fresh aggregate and
            // append the weights to the decision fan-out
            let mix = match self.cfg.policy.mix_eta() {
                Some(eta) => self.personalized_mix(g, &per_client, &survivors, eta)?,
                None => Vec::new(),
            };
            let group = &self.groups[g];
            decisions.push(SyncDecision {
                k: a.k,
                group: g,
                new_interval: self.schedule.intervals[g],
                new_params: group.params.iter().map(|&t| self.global[t].data.clone()).collect(),
                mix,
            });
        }
        // registry touch: once per surviving client per committed block,
        // so the resident roster stays O(participating)
        for (slot, &c) in survivors.iter().enumerate() {
            let data_size = self.partition.clients[c].total;
            self.registry.note_seen(c, a.round, data_size)?;
            self.registry.note_bytes(c, reg_uplink[slot], reg_downlink)?;
        }
        Ok(decisions)
    }

    /// Personalized policy (pFedLA-style): update each survivor's mixing
    /// weight for group `g` toward its *affinity* with the fresh aggregate
    /// — `lambda <- (1 - eta) * lambda + eta * 1/(1 + d_c/dim)` where
    /// `d_c` is the squared distance between the client's uplink and the
    /// aggregate.  A client whose update agrees with the crowd drifts
    /// toward full adoption (lambda -> 1); a divergent client keeps more
    /// of its own params.  State persists per client in the registry
    /// (lambda starts at 1.0 = plain FedAvg), so it survives sampling
    /// gaps and checkpoint/resume.  All reductions are f64 per client in
    /// survivor order — transport-invariant.
    fn personalized_mix(
        &mut self,
        g: usize,
        per_client: &[&LayerUpdate],
        survivors: &[usize],
        eta: f64,
    ) -> Result<Vec<(usize, f32)>> {
        let group = self.groups[g].clone();
        let mut mix = Vec::with_capacity(survivors.len());
        for (slot, &c) in survivors.iter().enumerate() {
            let u = per_client[slot];
            let mut d = 0.0f64;
            for (ti, &t) in group.params.iter().enumerate() {
                let owned;
                let row: &[f32] = match u.tensors[ti].as_dense() {
                    Some(r) => r,
                    None => {
                        owned = u.tensors[ti].decode()?;
                        &owned
                    }
                };
                for (&x, &uj) in row.iter().zip(&self.global[t].data) {
                    let diff = (x - uj) as f64;
                    d += diff * diff;
                }
            }
            let affinity = 1.0 / (1.0 + d / group.dim.max(1) as f64);
            let mut lam = match self.registry.mix_weights(c)? {
                Some(l) => l,
                None => vec![1.0f32; self.groups.len()],
            };
            anyhow::ensure!(
                lam.len() == self.groups.len(),
                "client {c} mix-weight vector has {} entries, model has {} groups",
                lam.len(),
                self.groups.len()
            );
            lam[g] = ((1.0 - eta) * lam[g] as f64 + eta * affinity) as f32;
            self.registry.put_mix_weights(c, &lam)?;
            mix.push((c, lam[g]));
        }
        Ok(mix)
    }

    /// Tensor-by-tensor weighted average in manifest order — the exact
    /// accumulation order of the historical in-proc path.  `weights` is
    /// parallel to `per_client` (the survivor subset under quorum).
    fn aggregate_group_native(
        &mut self,
        g: usize,
        per_client: &[&LayerUpdate],
        weights: &[f32],
    ) -> Result<f64> {
        let group = self.groups[g].clone();
        let mut disc = 0.0f64;
        for (ti, &t) in group.params.iter().enumerate() {
            let want = self.global[t].data.len();
            // decode lossy payloads once; borrow dense ones in place
            let owned: Vec<Option<Vec<f32>>> = per_client
                .iter()
                .map(|u| match u.tensors[ti].as_dense() {
                    Some(_) => Ok(None),
                    None => u.tensors[ti].decode().map(Some),
                })
                .collect::<Result<_>>()?;
            let rows: Vec<&[f32]> = per_client
                .iter()
                .zip(&owned)
                .map(|(u, o)| o.as_deref().unwrap_or_else(|| u.tensors[ti].as_dense().unwrap()))
                .collect();
            for (row, u) in rows.iter().zip(per_client) {
                anyhow::ensure!(
                    row.len() == want,
                    "group {g} tensor {ti}: client {} sent {} values, expected {want}",
                    u.client,
                    row.len()
                );
            }
            disc +=
                crate::aggregation::aggregate_native(&rows, weights, &mut self.global[t].data);
        }
        Ok(disc)
    }

    /// Stack the group's rows [m, dim] and run the injected fused kernel
    /// (the Pallas L1 path), then scatter u back into the global tensors.
    fn aggregate_group_fused(
        &mut self,
        g: usize,
        per_client: &[&LayerUpdate],
        weights: &[f32],
        fused: &mut FusedAgg<'_>,
    ) -> Result<f64> {
        let group = self.groups[g].clone();
        let dim = group.dim;
        let m = per_client.len();
        self.stack_scratch.resize(m * dim, 0.0);
        for (row, u) in per_client.iter().enumerate() {
            let mut off = row * dim;
            for (ti, _) in group.params.iter().enumerate() {
                let src = u.tensors[ti].as_dense().context("fused path requires dense rows")?;
                self.stack_scratch[off..off + src.len()].copy_from_slice(src);
                off += src.len();
            }
        }
        let (u, disc) = fused(&self.stack_scratch, weights, dim)?;
        let mut off = 0;
        for &t in &group.params {
            let len = self.global[t].data.len();
            self.global[t].data.copy_from_slice(&u[off..off + len]);
            off += len;
        }
        Ok(disc as f64)
    }

    /// Robust path: decode each survivor's group tensors into one owned
    /// flat row (layer order), run the `--aggregator` reducer pipeline,
    /// scatter the folded vector back into the global tensors, and charge
    /// the ledger's rejected/clipped counters from the per-row flags.
    /// Rows are in survivor order and the reducer's tie-breaks key on
    /// client id, so the result is independent of arrival order.
    fn aggregate_group_robust(
        &mut self,
        g: usize,
        per_client: &[&LayerUpdate],
        weights: &[f32],
        survivors: &[usize],
    ) -> Result<f64> {
        let group = self.groups[g].clone();
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(per_client.len());
        for u in per_client {
            let mut row = Vec::with_capacity(group.dim);
            for (ti, &t) in group.params.iter().enumerate() {
                let want = self.global[t].data.len();
                let vals = u.tensors[ti].decode()?;
                anyhow::ensure!(
                    vals.len() == want,
                    "group {g} tensor {ti}: client {} sent {} values, expected {want}",
                    u.client,
                    vals.len()
                );
                row.extend_from_slice(&vals);
            }
            rows.push(row);
        }
        let mut out = vec![0.0f32; group.dim];
        let (disc, flags) =
            crate::aggregation::robust::reduce(&self.robust, &mut rows, weights, survivors, &mut out)?;
        let mut off = 0;
        for &t in &group.params {
            let len = self.global[t].data.len();
            self.global[t].data.copy_from_slice(&out[off..off + len]);
            off += len;
        }
        for (i, fl) in flags.iter().enumerate() {
            if fl.rejected {
                self.ledger.record_rejected(survivors[i]);
            }
            if fl.clipped {
                self.ledger.record_clipped(survivors[i]);
            }
        }
        Ok(disc)
    }

    /// FedNova: adopt a participant-computed full-model sync and charge
    /// the ledger for a whole-model aggregation (every group).
    pub fn adopt_full_model(&mut self, new_global: Vec<HostTensor>) -> Result<()> {
        self.global = new_global;
        self.ledger.record_round();
        let mut dense_total = 0u64;
        for g in 0..self.groups.len() {
            self.ledger.record_sync(g, self.active.len());
            let dense = self.groups[g].dim * 4;
            dense_total += dense as u64;
            for &c in &self.active {
                self.ledger.record_participant_bytes(c, dense, dense);
            }
        }
        for i in 0..self.active.len() {
            let c = self.active[i];
            let data_size = self.partition.clients[c].total;
            self.registry.note_seen(c, self.round, data_size)?;
            self.registry.note_bytes(c, dense_total, dense_total)?;
        }
        Ok(())
    }

    /// FedNova normalized averaging (Wang et al. 2020) from wire-shipped
    /// round deltas: `tau_eff = sum w_i * a_i`, then
    /// `x <- x + tau_eff * sum w_i * d_i / a_i` folded in active order —
    /// the exact accumulation order (and hence bits) of the historical
    /// in-proc reduction.  `algo` holds one [`AlgoState`] per surviving
    /// active client (quorum: clients whose shard departed simply do not
    /// appear, and the weights renormalize over the survivors).  Returns
    /// one catch-up [`SyncDecision`] per group carrying the new global —
    /// the broadcast that replaces the old in-proc client pull.
    pub fn nova_fold(&mut self, k: usize, algo: &[AlgoState]) -> Result<Vec<SyncDecision>> {
        anyhow::ensure!(
            self.cfg.algorithm == Algorithm::Nova,
            "nova_fold called under {}",
            self.cfg.algorithm.name()
        );
        let states = self.algo_by_survivor(k, algo)?;
        anyhow::ensure!(!states.is_empty(), "no surviving FedNova states at k={k}");
        let survivors: Vec<usize> = states.iter().map(|s| s.client).collect();
        let weights = self.partition.active_weights(&survivors);
        let tau_eff: f64 = states
            .iter()
            .zip(&weights)
            .map(|(s, &w)| w as f64 * s.steps as f64)
            .sum();
        for t in 0..self.global.len() {
            let len = self.global[t].data.len();
            let mut delta = vec![0.0f64; len];
            for (s, &w) in states.iter().zip(&weights) {
                let a_i = s.steps.max(1) as f64;
                let d = &s.tensors[t];
                anyhow::ensure!(
                    d.len() == len,
                    "FedNova state tensor {t} from client {} has {} values, expected {len}",
                    s.client,
                    d.len()
                );
                for j in 0..len {
                    delta[j] += w as f64 * d[j] as f64 / a_i;
                }
            }
            let gdata = &mut self.global[t].data;
            for j in 0..len {
                gdata[j] += (tau_eff * delta[j]) as f32;
            }
        }
        self.charge_full_model(&survivors)?;
        Ok((0..self.groups.len())
            .map(|g| {
                SyncDecision::plain(
                    k,
                    g,
                    self.schedule.intervals[g],
                    self.groups[g]
                        .params
                        .iter()
                        .map(|&t| self.global[t].data.clone())
                        .collect(),
                )
            })
            .collect())
    }

    /// SCAFFOLD server fold from wire-shipped refreshed controls: each
    /// surviving client ships its `c_i+`; the coordinator computes
    /// `c <- c + sum (c_i+ - c_i) / N` against the registry-spilled
    /// previous `c_i` (zeros before first participation), spills `c_i+`
    /// back, and returns the [`ControlUpdate`] broadcast that refreshes
    /// every participant's replica.  Fold order is active order, so the
    /// bytes are transport-invariant.
    pub fn scaffold_fold(&mut self, k: usize, algo: &[AlgoState]) -> Result<ControlUpdate> {
        anyhow::ensure!(
            self.cfg.algorithm == Algorithm::Scaffold,
            "scaffold_fold called under {}",
            self.cfg.algorithm.name()
        );
        if self.server_control.is_none() {
            self.server_control =
                Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
        }
        let states = self.algo_by_survivor(k, algo)?;
        let n = self.cfg.n_clients as f32;
        let control_bytes: u64 =
            self.global.iter().map(|t| t.data.len() as u64 * 4).sum();
        for s in &states {
            let c_old = self.registry.control(s.client)?;
            let server = self.server_control.as_mut().expect("initialized above");
            anyhow::ensure!(
                s.tensors.len() == server.len(),
                "SCAFFOLD state from client {} has {} tensors, model has {}",
                s.client,
                s.tensors.len(),
                server.len()
            );
            let mut spill = Vec::with_capacity(s.tensors.len());
            for (t, new) in s.tensors.iter().enumerate() {
                let len = server[t].data.len();
                anyhow::ensure!(
                    new.len() == len,
                    "SCAFFOLD control tensor {t} from client {} has {} values, expected {len}",
                    s.client,
                    new.len()
                );
                let s_t = &mut server[t].data;
                match c_old.as_ref() {
                    Some(old) => {
                        for j in 0..len {
                            s_t[j] += (new[j] - old[t].data[j]) / n;
                        }
                    }
                    None => {
                        // previous c_i was implicit zeros
                        for j in 0..len {
                            s_t[j] += new[j] / n;
                        }
                    }
                }
                spill.push(HostTensor { shape: server[t].shape.clone(), data: new.clone() });
            }
            self.registry.put_control(s.client, &spill)?;
            // control traffic: c_i+ uplink, c broadcast downlink
            self.ledger.record_participant_bytes(
                s.client,
                control_bytes as usize,
                control_bytes as usize,
            );
            self.registry.note_bytes(s.client, control_bytes, control_bytes)?;
        }
        Ok(ControlUpdate {
            k,
            tensors: self
                .server_control
                .as_ref()
                .expect("initialized above")
                .iter()
                .map(|t| t.data.clone())
                .collect(),
        })
    }

    /// Validate a round's `AlgoState`s and order them by the active list
    /// (arrival order must never influence a fold).  Clients outside the
    /// active set and duplicates are protocol violations; active clients
    /// that shipped nothing (departed shards under quorum) are skipped.
    fn algo_by_survivor<'a>(
        &self,
        k: usize,
        algo: &'a [AlgoState],
    ) -> Result<Vec<&'a AlgoState>> {
        let mut by_client: Vec<Option<&AlgoState>> = vec![None; self.active.len()];
        for s in algo {
            anyhow::ensure!(s.k == k, "algo state k={} for block k={k}", s.k);
            let slot = self
                .active
                .iter()
                .position(|&ci| ci == s.client)
                .with_context(|| format!("algo state from inactive client {}", s.client))?;
            anyhow::ensure!(
                by_client[slot].is_none(),
                "duplicate algo state from client {}",
                s.client
            );
            by_client[slot] = Some(s);
        }
        Ok(by_client.into_iter().flatten().collect())
    }

    /// Ledger + registry accounting for one whole-model sync over
    /// `survivors` (the FedNova round boundary: every group's params move,
    /// dense, both directions).
    fn charge_full_model(&mut self, survivors: &[usize]) -> Result<()> {
        self.ledger.record_round();
        let mut dense_total = 0u64;
        for g in 0..self.groups.len() {
            self.ledger.record_sync(g, survivors.len());
            let dense = self.groups[g].dim * 4;
            dense_total += dense as u64;
            for &c in survivors {
                self.ledger.record_participant_bytes(c, dense, dense);
            }
        }
        for &c in survivors {
            let data_size = self.partition.clients[c].total;
            self.registry.note_seen(c, self.round, data_size)?;
            self.registry.note_bytes(c, dense_total, dense_total)?;
        }
        Ok(())
    }

    /// The current server control variate broadcast, if one exists — the
    /// catch-up frame a rejoining peer needs under SCAFFOLD.
    pub fn catchup_control(&self) -> Option<ControlUpdate> {
        self.server_control.as_ref().map(|tensors| ControlUpdate {
            k: self.block * self.gap,
            tensors: tensors.iter().map(|t| t.data.clone()).collect(),
        })
    }

    /// Registry-spilled client control variates as catch-up `AlgoState`s
    /// (ascending client id) — a rejoining peer adopts the ones in its
    /// shard so its clients' `c_i` resume where the run left off.
    pub fn catchup_algo(&mut self) -> Result<Vec<AlgoState>> {
        let k = self.block * self.gap;
        let mut out = Vec::new();
        for id in self.registry.spilled_control_ids() {
            let tensors = self
                .registry
                .control(id)?
                .expect("listed control id must resolve")
                .into_iter()
                .map(|t| t.data)
                .collect();
            out.push(AlgoState { k, client: id, steps: 0, tensors });
        }
        Ok(out)
    }

    /// Close the block: run Algorithm 2 at boundaries and report whether a
    /// round completed (and whether it wants an evaluation).
    pub fn end_block(&mut self, k: usize) -> BlockOutcome {
        self.schedule.maybe_adjust(k);
        if k % self.round_len != 0 {
            return BlockOutcome::MidRound;
        }
        self.round += 1;
        let train_loss = if self.round_loss_n > 0 {
            self.round_loss_sum / self.round_loss_n as f64
        } else {
            0.0
        };
        self.round_loss_sum = 0.0;
        self.round_loss_n = 0;
        let eval_due = (self.cfg.eval_every_rounds > 0
            && self.round % self.cfg.eval_every_rounds == 0)
            || self.round == self.total_rounds;
        BlockOutcome::RoundComplete {
            round: self.round,
            total_rounds: self.total_rounds,
            train_loss,
            eval_due,
        }
    }

    /// Record the round's curve point (with the driver's evaluation result,
    /// if one was due) and queue a resample for the next block.
    pub fn complete_round(&mut self, k: usize, train_loss: f64, eval: Option<(f64, f64)>) {
        self.curve.push(CurvePoint {
            iteration: k,
            round: self.round,
            train_loss,
            val_acc: eval.map(|(a, _)| a),
            val_loss: eval.map(|(_, l)| l),
            comm_cost: self.ledger.total_cost(),
        });
        if self.round < self.total_rounds {
            self.pending_new_round = true;
        }
    }

    /// One `SyncDecision` per group carrying the *current* global params
    /// and live interval — the catch-up bundle a rejoining peer applies
    /// before its first assignment.  The peer has no active clients yet,
    /// so applying these is replica-only; its first `new_round`
    /// assignment then pulls the refreshed replica into every owned
    /// client, exactly like a worker that was present all along.
    pub fn catchup_decisions(&self) -> Vec<SyncDecision> {
        let k = self.block * self.gap;
        (0..self.groups.len())
            .map(|g| {
                SyncDecision::plain(
                    k,
                    g,
                    self.schedule.intervals[g],
                    self.groups[g]
                        .params
                        .iter()
                        .map(|&t| self.global[t].data.clone())
                        .collect(),
                )
            })
            .collect()
    }

    /// Ledger note: shard `s` departed mid-run.
    pub fn note_departure(&mut self, s: usize) {
        self.ledger.record_departure(s);
    }

    /// Ledger note: a fresh connection claimed vacant shard `s`.
    pub fn note_rejoin(&mut self, s: usize) {
        self.ledger.record_rejoin(s);
    }

    /// Ledger note: shard `s` missed a committed block (quorum mode).
    pub fn note_missed_block(&mut self, s: usize) {
        self.ledger.record_missed_block(s);
    }

    /// Blocks already committed — a resumed run's participants must
    /// fast-forward their client rng streams past exactly this many.
    pub fn completed_blocks(&self) -> usize {
        self.block
    }

    /// Serialize the full coordinator state for a round-boundary
    /// checkpoint: config fingerprint, progress counters, global model,
    /// live schedule, sampler rng, ledger, learning curve, and registry.
    /// Everything a restart needs to continue bit-identically — per-round
    /// wall times and schedule adjustment diagnostics are deliberately
    /// not included (they describe the dead process, not the run).
    pub fn encode_checkpoint(&mut self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.bytes(&cfg_wire_bytes(&self.cfg)?)?;
        e.usize(self.cfg.workers);
        e.usize(self.block);
        e.usize(self.round);
        e.bool(self.pending_new_round);
        e.f64(self.round_loss_sum);
        e.usize(self.round_loss_n);
        e.u32(self.global.len() as u32);
        for t in &self.global {
            e.usizes(&t.shape)?;
            e.f32s(&t.data)?;
        }
        e.usizes(&self.schedule.intervals)?;
        e.u32(self.schedule.last_unit_disc.len() as u32);
        for &x in &self.schedule.last_unit_disc {
            e.f64(x);
        }
        let (s, spare) = self.sampler.rng_state();
        for &w in &s {
            e.u64(w);
        }
        e.bool(spare.is_some());
        e.f64(spare.unwrap_or(0.0));
        self.ledger.encode(&mut e)?;
        e.u32(self.curve.len() as u32);
        for p in &self.curve {
            e.usize(p.iteration);
            e.usize(p.round);
            e.f64(p.train_loss);
            e.bool(p.val_acc.is_some());
            e.f64(p.val_acc.unwrap_or(0.0));
            e.bool(p.val_loss.is_some());
            e.f64(p.val_loss.unwrap_or(0.0));
            e.u64(p.comm_cost);
        }
        self.registry.encode_state(&mut e)?;
        // v3 additions ride at the tail: divergence-feedback observation
        // flags and the SCAFFOLD server control variate
        e.u32(self.schedule.observed.len() as u32);
        for &o in &self.schedule.observed {
            e.bool(o);
        }
        e.bool(self.server_control.is_some());
        if let Some(sc) = &self.server_control {
            e.u32(sc.len() as u32);
            for t in sc {
                e.usizes(&t.shape)?;
                e.f32s(&t.data)?;
            }
        }
        Ok(e.buf)
    }

    /// Restore a [`encode_checkpoint`](Self::encode_checkpoint) snapshot
    /// into a freshly constructed core for the *same* config.  Loud
    /// errors on any mismatch — resuming under a different run
    /// configuration would silently diverge, so the fingerprint gate is
    /// exact.
    pub fn restore_checkpoint(&mut self, body: &[u8]) -> Result<()> {
        let mut d = Dec::new(body);
        let fp = d.bytes()?;
        anyhow::ensure!(
            fp == cfg_wire_bytes(&self.cfg)?,
            "checkpoint was written by a different run configuration; \
             resume must repeat the original run flags"
        );
        let workers = d.usize()?;
        anyhow::ensure!(
            workers == self.cfg.workers,
            "checkpoint was written with --workers {workers}, this run has {}",
            self.cfg.workers
        );
        self.block = d.usize()?;
        self.round = d.usize()?;
        self.pending_new_round = d.bool()?;
        self.round_loss_sum = d.f64()?;
        self.round_loss_n = d.usize()?;
        let n_tensors = d.u32()? as usize;
        anyhow::ensure!(
            n_tensors == self.global.len(),
            "checkpoint holds {n_tensors} global tensors, model has {}",
            self.global.len()
        );
        for (ti, t) in self.global.iter_mut().enumerate() {
            let shape = d.usizes()?;
            let data = d.f32s()?;
            anyhow::ensure!(
                shape == t.shape && data.len() == t.data.len(),
                "checkpoint tensor {ti} shape {shape:?} != model shape {:?}",
                t.shape
            );
            t.data = data;
        }
        let intervals = d.usizes()?;
        anyhow::ensure!(
            intervals.len() == self.groups.len(),
            "checkpoint holds {} interval entries, model has {} groups",
            intervals.len(),
            self.groups.len()
        );
        self.schedule.intervals = intervals;
        let n_disc = d.u32()? as usize;
        anyhow::ensure!(
            n_disc == self.schedule.last_unit_disc.len(),
            "checkpoint discrepancy table length mismatch"
        );
        let mut disc = Vec::with_capacity(n_disc);
        for _ in 0..n_disc {
            disc.push(d.f64()?);
        }
        self.schedule.last_unit_disc = disc;
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = d.u64()?;
        }
        let has_spare = d.bool()?;
        let spare = d.f64()?;
        self.sampler.restore_rng(s, if has_spare { Some(spare) } else { None });
        let ledger = CommLedger::decode(&mut d)?;
        anyhow::ensure!(
            ledger.groups.len() == self.groups.len()
                && ledger.participants.len() == self.cfg.workers.max(1),
            "checkpoint ledger shape mismatch"
        );
        self.ledger = ledger;
        let n_points = d.u32()? as usize;
        let mut curve = Vec::with_capacity(n_points);
        for _ in 0..n_points {
            let iteration = d.usize()?;
            let round = d.usize()?;
            let train_loss = d.f64()?;
            let has_acc = d.bool()?;
            let acc = d.f64()?;
            let has_loss = d.bool()?;
            let loss = d.f64()?;
            curve.push(CurvePoint {
                iteration,
                round,
                train_loss,
                val_acc: has_acc.then_some(acc),
                val_loss: has_loss.then_some(loss),
                comm_cost: d.u64()?,
            });
        }
        self.curve = curve;
        self.registry.decode_state(&mut d)?;
        let n_obs = d.u32()? as usize;
        anyhow::ensure!(
            n_obs == self.schedule.observed.len(),
            "checkpoint observation table length mismatch"
        );
        let mut observed = Vec::with_capacity(n_obs);
        for _ in 0..n_obs {
            observed.push(d.bool()?);
        }
        self.schedule.observed = observed;
        if d.bool()? {
            let n_ctl = d.u32()? as usize;
            anyhow::ensure!(
                n_ctl == self.global.len(),
                "checkpoint server control holds {n_ctl} tensors, model has {}",
                self.global.len()
            );
            let mut sc = Vec::with_capacity(n_ctl);
            for (ti, t) in self.global.iter().enumerate() {
                let shape = d.usizes()?;
                let data = d.f32s()?;
                anyhow::ensure!(
                    shape == t.shape && data.len() == t.data.len(),
                    "checkpoint server control tensor {ti} shape mismatch"
                );
                sc.push(HostTensor { shape, data });
            }
            self.server_control = Some(sc);
        } else {
            self.server_control = None;
        }
        d.finish()?;
        Ok(())
    }

    /// Snapshot the run's metrics (curve + ledger totals); the driver adds
    /// the final evaluation and wall/runtime seconds.
    pub fn metrics(&self) -> RunMetrics {
        let mut m = RunMetrics {
            tag: self.cfg.tag(),
            curve: self.curve.clone(),
            ..Default::default()
        };
        m.record_ledger(&self.ledger);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::Policy;
    use crate::protocol::messages::{Heartbeat, Hello, Payload};

    fn tiny_core(n_clients: usize, policy: Policy, iterations: usize) -> CoordinatorCore {
        let cfg = RunConfig {
            n_clients,
            policy,
            iterations,
            samples: 32,
            warmup_rounds: 0,
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let groups = vec![
            GroupInfo { name: "g0".into(), dim: 3, params: vec![0] },
            GroupInfo { name: "g1".into(), dim: 2, params: vec![1] },
        ];
        let global = vec![
            HostTensor::from_vec(&[3], vec![0.0; 3]),
            HostTensor::from_vec(&[2], vec![0.0; 2]),
        ];
        CoordinatorCore::new(&cfg, groups, global)
    }

    fn dense_update(k: usize, group: usize, client: usize, vals: Vec<Vec<f32>>) -> LayerUpdate {
        LayerUpdate { k, group, client, tensors: vals.into_iter().map(Payload::Dense).collect() }
    }

    #[test]
    fn assignment_flow_covers_all_blocks_and_rounds() {
        let mut core = tiny_core(4, Policy::fedavg(6), 24);
        let mut ks = Vec::new();
        while let Some(a) = core.begin_block() {
            ks.push(a.k);
            assert_eq!(a.gap, 6);
            assert_eq!(a.active, vec![0, 1, 2, 3]);
            assert!(a.new_round, "fedavg(6): every block is a round");
            assert_eq!(a.due_groups, vec![0, 1]);
            core.record_losses(&[1.0; 4]);
            let ups = vec![
                dense_update(a.k, 0, 0, vec![vec![1.0, 2.0, 3.0]]),
                dense_update(a.k, 0, 1, vec![vec![1.0, 2.0, 3.0]]),
                dense_update(a.k, 0, 2, vec![vec![1.0, 2.0, 3.0]]),
                dense_update(a.k, 0, 3, vec![vec![1.0, 2.0, 3.0]]),
                dense_update(a.k, 1, 0, vec![vec![5.0, 5.0]]),
                dense_update(a.k, 1, 1, vec![vec![5.0, 5.0]]),
                dense_update(a.k, 1, 2, vec![vec![5.0, 5.0]]),
                dense_update(a.k, 1, 3, vec![vec![5.0, 5.0]]),
            ];
            let decisions = core.apply_updates(&a, &ups, None).unwrap();
            assert_eq!(decisions.len(), 2);
            assert_eq!(decisions[0].new_params[0], vec![1.0, 2.0, 3.0]);
            match core.end_block(a.k) {
                BlockOutcome::RoundComplete { round, train_loss, .. } => {
                    assert!((train_loss - 1.0).abs() < 1e-12);
                    core.complete_round(a.k, train_loss, None);
                    assert_eq!(round, ks.len());
                }
                BlockOutcome::MidRound => panic!("fedavg block must close a round"),
            }
        }
        assert_eq!(ks, vec![6, 12, 18, 24]);
        assert!(core.begin_block().is_none());
        // identical rows -> zero discrepancy -> global adopted the rows
        assert_eq!(core.global[0].data, vec![1.0, 2.0, 3.0]);
        // ledger: 4 rounds x both groups, dense bytes
        assert_eq!(core.ledger.rounds, 4);
        assert_eq!(core.ledger.total_cost(), 4 * (3 + 2));
        assert_eq!(core.curve.len(), 4);
    }

    #[test]
    fn apply_updates_rejects_protocol_violations() {
        let mut core = tiny_core(2, Policy::fedavg(6), 12);
        let a = core.begin_block().unwrap();
        // short one update: the count guard fires
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
        ];
        let err = core.apply_updates(&a, &ups, None).unwrap_err();
        assert!(format!("{err:#}").contains("expected 4 layer updates"), "{err:#}");
        // right count, but one frame names a non-due group — so a due
        // group is short a client
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 1, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 7, 0, vec![vec![0.0; 2]]),
        ];
        let err = core.apply_updates(&a, &ups, None).unwrap_err();
        assert!(format!("{err:#}").contains("missing update"), "{err:#}");
        // wrong tensor length
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 1, vec![vec![0.0; 4]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
        ];
        assert!(core.apply_updates(&a, &ups, None).is_err());
        // inactive client
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 7, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
        ];
        let err = core.apply_updates(&a, &ups, None).unwrap_err();
        assert!(format!("{err:#}").contains("inactive client"), "{err:#}");
    }

    #[test]
    fn join_handshake_walks_the_phases() {
        let hello = |id: usize, len: usize| {
            Message::Hello(Hello {
                version: crate::protocol::WIRE_VERSION,
                worker_id: id,
                shard_len: len,
            })
        };
        let mut h = JoinHandshake::new(1, 3);
        assert_eq!(h.phase(), JoinPhase::AwaitJoin);
        // join Hello carries sentinels (the peer has no assignment yet)
        assert_eq!(h.on_message(&hello(0, 0)).unwrap(), JoinAction::SendConfigure);
        assert_eq!(h.phase(), JoinPhase::AwaitReady);
        assert_eq!(h.on_message(&hello(1, 3)).unwrap(), JoinAction::Ready);
        assert!(h.is_ready());
        // liveness echoes pass through with their nonce
        assert_eq!(
            h.on_message(&Message::Heartbeat(Heartbeat { nonce: 42 })).unwrap(),
            JoinAction::Pong(42)
        );
    }

    #[test]
    fn join_handshake_rejects_violations() {
        let hello = |v: u8, id: usize, len: usize| {
            Message::Hello(Hello { version: v, worker_id: id, shard_len: len })
        };
        // version skew rejected at first contact
        let mut h = JoinHandshake::new(0, 2);
        let err = h.on_message(&hello(crate::protocol::WIRE_VERSION + 1, 0, 0)).unwrap_err();
        assert!(format!("{err:#}").contains("protocol v"), "{err:#}");
        // wrong first message for the phase
        let mut h = JoinHandshake::new(0, 2);
        let err = h.on_message(&Message::Shutdown).unwrap_err();
        assert!(format!("{err:#}").contains("handshake"), "{err:#}");
        // readiness Hello must confirm the assigned shard exactly
        let mut h = JoinHandshake::new(2, 4);
        h.on_message(&hello(crate::protocol::WIRE_VERSION, 0, 0)).unwrap();
        let err = h.on_message(&hello(crate::protocol::WIRE_VERSION, 1, 4)).unwrap_err();
        assert!(format!("{err:#}").contains("shard"), "{err:#}");
        let mut h = JoinHandshake::new(2, 4);
        h.on_message(&hello(crate::protocol::WIRE_VERSION, 0, 0)).unwrap();
        let err = h.on_message(&hello(crate::protocol::WIRE_VERSION, 2, 3)).unwrap_err();
        assert!(format!("{err:#}").contains("claims"), "{err:#}");
    }

    #[test]
    fn apply_updates_folds_per_participant_counters() {
        let cfg = RunConfig {
            n_clients: 2,
            workers: 2,
            policy: Policy::fedavg(6),
            iterations: 12,
            samples: 32,
            warmup_rounds: 0,
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let groups = vec![
            GroupInfo { name: "g0".into(), dim: 3, params: vec![0] },
            GroupInfo { name: "g1".into(), dim: 2, params: vec![1] },
        ];
        let global = vec![
            HostTensor::from_vec(&[3], vec![0.0; 3]),
            HostTensor::from_vec(&[2], vec![0.0; 2]),
        ];
        let mut core = CoordinatorCore::new(&cfg, groups, global);
        assert_eq!(core.ledger.participants.len(), 2);
        let a = core.begin_block().unwrap();
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 1, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
        ];
        core.apply_updates(&a, &ups, None).unwrap();
        // client c -> shard c % 2; uplink: g0 12 B + g1 8 B dense each;
        // downlink: both groups' dense params to both active clients
        for s in 0..2 {
            let p = &core.ledger.participants[s];
            assert_eq!(p.shard, s);
            assert_eq!(p.updates, 2);
            assert_eq!(p.uplink_bytes, 12 + 8);
            assert_eq!(p.downlink_bytes, 12 + 8);
        }
    }

    #[test]
    fn peer_session_walks_join_ready_working_departed() {
        let hello = |id: usize, len: usize| {
            Message::Hello(Hello {
                version: crate::protocol::WIRE_VERSION,
                worker_id: id,
                shard_len: len,
            })
        };
        let mut s = PeerSession::new(2, 3);
        assert_eq!(s.phase(), PeerPhase::Joining);
        assert_eq!(s.shard(), 2);
        // promotion is only legal from Ready
        assert!(s.promote().is_err());
        assert_eq!(s.on_message(&hello(0, 0)).unwrap(), JoinAction::SendConfigure);
        assert_eq!(s.phase(), PeerPhase::Joining);
        assert_eq!(s.on_message(&hello(2, 3)).unwrap(), JoinAction::Ready);
        assert_eq!(s.phase(), PeerPhase::Ready);
        // Ready peers still echo liveness pings while parked
        assert_eq!(
            s.on_message(&Message::Heartbeat(Heartbeat { nonce: 7 })).unwrap(),
            JoinAction::Pong(7)
        );
        s.promote().unwrap();
        assert!(s.is_working());
        // Working peers' frames belong to the block loop, not the pump
        assert!(s.on_message(&hello(2, 3)).is_err());
        s.depart();
        assert_eq!(s.phase(), PeerPhase::Departed);
        s.depart(); // idempotent
        assert_eq!(s.phase(), PeerPhase::Departed);
        assert!(s.promote().is_err());
    }

    #[test]
    fn quorum_aggregation_renormalizes_over_survivors() {
        let mut core = tiny_core(3, Policy::fedavg(6), 12);
        let a = core.begin_block().unwrap();
        assert_eq!(a.active, vec![0, 1, 2]);
        // client 1's shard departed: only clients 0 and 2 report
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![1.0, 2.0, 3.0]]),
            dense_update(a.k, 0, 2, vec![vec![3.0, 4.0, 5.0]]),
            dense_update(a.k, 1, 0, vec![vec![10.0, 10.0]]),
            dense_update(a.k, 1, 2, vec![vec![20.0, 20.0]]),
        ];
        let decisions = core.apply_updates_quorum(&a, &ups, &[1], None).unwrap();
        assert_eq!(decisions.len(), 2);
        // uniform partition: survivor weights renormalize to 1/2 each
        assert_eq!(core.global[0].data, vec![2.0, 3.0, 4.0]);
        assert_eq!(core.global[1].data, vec![15.0, 15.0]);
        // an update from the absent client is a protocol violation
        let bad = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 1, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
        ];
        let err = core.apply_updates_quorum(&a, &bad, &[1], None).unwrap_err();
        assert!(format!("{err:#}").contains("inactive client"), "{err:#}");
        // every shard gone is fatal, not a silent no-op commit
        let err = core.apply_updates_quorum(&a, &[], &[0, 1, 2], None).unwrap_err();
        assert!(format!("{err:#}").contains("no surviving clients"), "{err:#}");
    }

    #[test]
    fn robust_aggregator_rejects_the_outlier_and_charges_the_ledger() {
        let cfg = RunConfig {
            n_clients: 3,
            policy: Policy::fedavg(6),
            iterations: 12,
            samples: 32,
            warmup_rounds: 0,
            aggregator: "trimmed:1".into(),
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let groups = vec![
            GroupInfo { name: "g0".into(), dim: 3, params: vec![0] },
            GroupInfo { name: "g1".into(), dim: 2, params: vec![1] },
        ];
        let global = vec![
            HostTensor::from_vec(&[3], vec![0.0; 3]),
            HostTensor::from_vec(&[2], vec![0.0; 2]),
        ];
        let mut core = CoordinatorCore::new(&cfg, groups, global);
        let a = core.begin_block().unwrap();
        assert_eq!(a.active, vec![0, 1, 2]);
        // client 2 is Byzantine in both groups: far from the coordinate-wise
        // median, so trimmed:1 drops it and means the honest pair
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![1.0, 2.0, 3.0]]),
            dense_update(a.k, 0, 1, vec![vec![1.0, 2.0, 3.0]]),
            dense_update(a.k, 0, 2, vec![vec![-9.0, -9.0, -9.0]]),
            dense_update(a.k, 1, 0, vec![vec![5.0, 5.0]]),
            dense_update(a.k, 1, 1, vec![vec![5.0, 5.0]]),
            dense_update(a.k, 1, 2, vec![vec![50.0, 50.0]]),
        ];
        let decisions = core.apply_updates(&a, &ups, None).unwrap();
        assert_eq!(core.global[0].data, vec![1.0, 2.0, 3.0]);
        assert_eq!(core.global[1].data, vec![5.0, 5.0]);
        assert_eq!(decisions[0].new_params[0], vec![1.0, 2.0, 3.0]);
        // in-proc = one shard: both groups' rejections fold into slot 0
        assert_eq!(core.ledger.participants[0].rejected_updates, 2);
        assert_eq!(core.ledger.participants[0].clipped_updates, 0);
    }

    #[test]
    fn normclip_aggregator_charges_clipped_updates() {
        let cfg = RunConfig {
            n_clients: 3,
            policy: Policy::fedavg(6),
            iterations: 6,
            samples: 32,
            warmup_rounds: 0,
            aggregator: "normclip:2".into(),
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let groups = vec![GroupInfo { name: "g0".into(), dim: 2, params: vec![0] }];
        let global = vec![HostTensor::from_vec(&[2], vec![0.0; 2])];
        let mut core = CoordinatorCore::new(&cfg, groups, global);
        let a = core.begin_block().unwrap();
        // norms 5, 5, 50: radius = 2 x median(5) = 10, so the scaled
        // attacker is clipped onto the radius (direction preserved)
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![3.0, 4.0]]),
            dense_update(a.k, 0, 1, vec![vec![3.0, 4.0]]),
            dense_update(a.k, 0, 2, vec![vec![30.0, 40.0]]),
        ];
        core.apply_updates(&a, &ups, None).unwrap();
        assert_eq!(core.ledger.participants[0].clipped_updates, 1);
        assert_eq!(core.ledger.participants[0].rejected_updates, 0);
        // mean of [3,4], [3,4], and the clipped [6,8]
        let want = [4.0f32, 16.0 / 3.0];
        for (g, w) in core.global[0].data.iter().zip(want) {
            assert!((g - w).abs() < 1e-5, "{:?} vs {want:?}", core.global[0].data);
        }
    }

    #[test]
    fn catchup_decisions_snapshot_the_live_schedule_and_params() {
        let mut core = tiny_core(2, Policy::fedavg(6), 12);
        let a = core.begin_block().unwrap();
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![1.0, 2.0, 3.0]]),
            dense_update(a.k, 0, 1, vec![vec![1.0, 2.0, 3.0]]),
            dense_update(a.k, 1, 0, vec![vec![5.0, 5.0]]),
            dense_update(a.k, 1, 1, vec![vec![5.0, 5.0]]),
        ];
        core.apply_updates(&a, &ups, None).unwrap();
        let catchup = core.catchup_decisions();
        assert_eq!(catchup.len(), 2);
        assert_eq!(catchup[0].k, a.k);
        assert_eq!(catchup[0].new_params[0], vec![1.0, 2.0, 3.0]);
        assert_eq!(catchup[1].new_params[0], vec![5.0, 5.0]);
        assert_eq!(catchup[0].new_interval, core.schedule.intervals[0]);
    }

    #[test]
    fn registry_follows_participation_across_sampling_gaps() {
        let mut core = tiny_core(4, Policy::fedavg(6), 12);
        let a = core.begin_block().unwrap();
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 1, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 2, vec![vec![0.0; 3]]),
            dense_update(a.k, 0, 3, vec![vec![0.0; 3]]),
            dense_update(a.k, 1, 0, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 1, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 2, vec![vec![0.0; 2]]),
            dense_update(a.k, 1, 3, vec![vec![0.0; 2]]),
        ];
        core.apply_updates(&a, &ups, None).unwrap();
        assert_eq!(core.registry.touched(), 4);
        let rec = core.registry.record(2).unwrap();
        assert_eq!(rec.last_seen_round, Some(0));
        assert_eq!(rec.updates, 1);
        // uplink: dense g0 (12 B) + g1 (8 B); downlink mirrors both groups
        assert_eq!(rec.uplink_bytes, 20);
        assert_eq!(rec.downlink_bytes, 20);
        assert_eq!(rec.data_size, core.partition.clients[2].total);
        // registry rows agree with the ledger's per-client fold
        assert_eq!(core.ledger.clients[&2].uplink_bytes, 20);
        assert_eq!(core.ledger.clients[&2].downlink_bytes, 20);
    }

    #[test]
    fn checkpoint_round_trips_into_a_fresh_core() {
        let run_round = |core: &mut CoordinatorCore| {
            let a = core.begin_block().unwrap();
            core.record_losses(&[1.0; 4]);
            let mut ups = Vec::new();
            for g in 0..2 {
                for c in 0..4 {
                    let dim = if g == 0 { 3 } else { 2 };
                    ups.push(dense_update(a.k, g, c, vec![vec![0.5; dim]]));
                }
            }
            core.apply_updates(&a, &ups, None).unwrap();
            match core.end_block(a.k) {
                BlockOutcome::RoundComplete { train_loss, .. } => {
                    core.complete_round(a.k, train_loss, Some((0.5, 1.0)));
                }
                BlockOutcome::MidRound => panic!("fedavg block closes a round"),
            }
            a
        };
        let mut core = tiny_core(4, Policy::fedavg(6), 24);
        run_round(&mut core);
        run_round(&mut core);
        let body = core.encode_checkpoint().unwrap();

        let mut restored = tiny_core(4, Policy::fedavg(6), 24);
        restored.restore_checkpoint(&body).unwrap();
        assert_eq!(restored.completed_blocks(), 2);
        assert_eq!(restored.curve, core.curve);
        assert_eq!(restored.global[0].data, core.global[0].data);
        assert_eq!(restored.ledger.total_cost(), core.ledger.total_cost());
        assert_eq!(restored.ledger.clients, core.ledger.clients);
        assert_eq!(
            restored.registry.record(1).unwrap(),
            core.registry.record(1).unwrap()
        );
        // both cores continue identically: same sampler stream, same
        // assignment, same aggregation result
        let a1 = run_round(&mut core);
        let a2 = run_round(&mut restored);
        assert_eq!(a1, a2);
        assert_eq!(restored.curve, core.curve);
        assert_eq!(restored.global[1].data, core.global[1].data);

        // a core built from a different config refuses the snapshot
        let mut wrong = tiny_core(8, Policy::fedavg(6), 24);
        let err = wrong.restore_checkpoint(&body).unwrap_err();
        assert!(
            format!("{err:#}").contains("different run configuration"),
            "{err:#}"
        );
    }

    fn tiny_core_with(
        n_clients: usize,
        policy: Policy,
        iterations: usize,
        algorithm: Algorithm,
    ) -> CoordinatorCore {
        let cfg = RunConfig {
            n_clients,
            policy,
            iterations,
            samples: 32,
            warmup_rounds: 0,
            algorithm,
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let groups = vec![
            GroupInfo { name: "g0".into(), dim: 3, params: vec![0] },
            GroupInfo { name: "g1".into(), dim: 2, params: vec![1] },
        ];
        let global = vec![
            HostTensor::from_vec(&[3], vec![0.0; 3]),
            HostTensor::from_vec(&[2], vec![0.0; 2]),
        ];
        CoordinatorCore::new(&cfg, groups, global)
    }

    #[test]
    fn nova_fold_normalizes_by_local_steps() {
        let mut core = tiny_core_with(2, Policy::fedavg(6), 12, Algorithm::Nova);
        let a = core.begin_block().unwrap();
        assert!(a.due_groups.is_empty(), "FedNova rounds carry no group uplinks");
        // uniform partition: w = 1/2 each; tau_eff = 0.5*2 + 0.5*4 = 3
        // delta = 0.5*[2,2,2]/2 + 0.5*[8,8,8]/4 = [1.5,1.5,1.5]
        // x <- 0 + 3 * 1.5 = 4.5 per coordinate of g0
        let states = vec![
            AlgoState { k: a.k, client: 0, steps: 2, tensors: vec![vec![2.0; 3], vec![2.0; 2]] },
            AlgoState { k: a.k, client: 1, steps: 4, tensors: vec![vec![8.0; 3], vec![8.0; 2]] },
        ];
        let decisions = core.nova_fold(a.k, &states).unwrap();
        for v in &core.global[0].data {
            assert!((v - 4.5).abs() < 1e-6, "{v}");
        }
        // one plain decision per group carrying the fresh global
        assert_eq!(decisions.len(), 2);
        assert_eq!(decisions[0].new_params[0], core.global[0].data);
        assert!(decisions[0].mix.is_empty());
        // whole-model accounting: one round, both groups synced dense
        assert_eq!(core.ledger.rounds, 1);
        assert_eq!(core.ledger.total_cost(), 3 + 2);
        // arrival order must not matter
        let mut core2 = tiny_core_with(2, Policy::fedavg(6), 12, Algorithm::Nova);
        let a2 = core2.begin_block().unwrap();
        let rev: Vec<AlgoState> = states.iter().rev().cloned().collect();
        core2.nova_fold(a2.k, &rev).unwrap();
        assert_eq!(core.global[0].data, core2.global[0].data);
    }

    #[test]
    fn scaffold_fold_accumulates_control_deltas_and_spills() {
        let mut core = tiny_core_with(2, Policy::fedavg(6), 12, Algorithm::Scaffold);
        let a = core.begin_block().unwrap();
        let states = vec![
            AlgoState { k: a.k, client: 0, steps: 2, tensors: vec![vec![1.0; 3], vec![1.0; 2]] },
            AlgoState { k: a.k, client: 1, steps: 2, tensors: vec![vec![3.0; 3], vec![3.0; 2]] },
        ];
        let cu = core.scaffold_fold(a.k, &states).unwrap();
        // previous c_i are implicit zeros: s = (1 + 3) / N=2 = 2.0
        for v in &cu.tensors[0] {
            assert!((v - 2.0).abs() < 1e-6, "{v}");
        }
        // refreshed c_i spilled into the registry
        let c1 = core.registry.control(1).unwrap().unwrap();
        assert_eq!(c1[0].data, vec![3.0; 3]);
        // catch-up bundle replays the same state for a rejoining peer
        let ctl = core.catchup_control().unwrap();
        assert_eq!(ctl.tensors, cu.tensors);
        let algo = core.catchup_algo().unwrap();
        assert_eq!(algo.len(), 2);
        assert_eq!(algo[1].client, 1);
        assert_eq!(algo[1].tensors[0], vec![3.0; 3]);
        // second fold applies deltas against the spilled previous controls:
        // client 0 moves 1 -> 2, client 1 stays: s += (1 + 0)/2 = 0.5
        let a2 = core.begin_block().unwrap();
        let states2 = vec![
            AlgoState { k: a2.k, client: 0, steps: 2, tensors: vec![vec![2.0; 3], vec![2.0; 2]] },
            AlgoState { k: a2.k, client: 1, steps: 2, tensors: vec![vec![3.0; 3], vec![3.0; 2]] },
        ];
        let cu2 = core.scaffold_fold(a2.k, &states2).unwrap();
        for v in &cu2.tensors[0] {
            assert!((v - 2.5).abs() < 1e-6, "{v}");
        }
        // the server control and observation flags ride checkpoints
        let body = core.encode_checkpoint().unwrap();
        let mut restored = tiny_core_with(2, Policy::fedavg(6), 12, Algorithm::Scaffold);
        restored.restore_checkpoint(&body).unwrap();
        assert_eq!(restored.catchup_control().unwrap().tensors, cu2.tensors);
        assert_eq!(
            restored.registry.control(0).unwrap().unwrap()[0].data,
            vec![2.0; 3]
        );
    }

    #[test]
    fn personalized_mix_rides_decisions_and_persists() {
        let mut core = tiny_core(2, Policy::personalized(6, 0.5), 12);
        let a = core.begin_block().unwrap();
        let ups = vec![
            dense_update(a.k, 0, 0, vec![vec![1.0, 1.0, 1.0]]),
            dense_update(a.k, 0, 1, vec![vec![3.0, 3.0, 3.0]]),
            dense_update(a.k, 1, 0, vec![vec![2.0, 2.0]]),
            dense_update(a.k, 1, 1, vec![vec![2.0, 2.0]]),
        ];
        let decisions = core.apply_updates(&a, &ups, None).unwrap();
        // every decision carries one weight per survivor
        assert_eq!(decisions[0].mix.len(), 2);
        assert_eq!(decisions[0].mix[0].0, 0);
        // g0 aggregate is [2,2,2]: both clients sit at distance^2 = 3, so
        // lambda = 0.5*1.0 + 0.5 * 1/(1 + 3/3) = 0.75 for both
        for &(_, lam) in &decisions[0].mix {
            assert!((lam - 0.75).abs() < 1e-6, "{lam}");
        }
        // g1 rows equal the aggregate: affinity 1.0 keeps lambda at 1.0
        for &(_, lam) in &decisions[1].mix {
            assert!((lam - 1.0).abs() < 1e-6, "{lam}");
        }
        // lambda persists in the registry and rides checkpoints
        let lam0 = core.registry.mix_weights(0).unwrap().unwrap();
        assert!((lam0[0] - 0.75).abs() < 1e-6);
        assert!((lam0[1] - 1.0).abs() < 1e-6);
        let body = core.encode_checkpoint().unwrap();
        let mut restored = tiny_core(2, Policy::personalized(6, 0.5), 12);
        restored.restore_checkpoint(&body).unwrap();
        assert_eq!(restored.registry.mix_weights(0).unwrap().unwrap(), lam0);
    }

    #[test]
    fn fedlama_assignments_follow_the_schedule() {
        let mut core = tiny_core(2, Policy::fedlama(6, 2), 24);
        let a1 = core.begin_block().unwrap();
        assert!(a1.new_round);
        assert_eq!(a1.k, 6);
        assert_eq!(a1.due_groups, vec![0, 1]);
        // feed zero-loss, identical updates; mid-round block follows
        core.record_losses(&[0.0, 0.0]);
        let ups: Vec<LayerUpdate> = vec![
            dense_update(6, 0, 0, vec![vec![0.0; 3]]),
            dense_update(6, 0, 1, vec![vec![0.0; 3]]),
            dense_update(6, 1, 0, vec![vec![0.0; 2]]),
            dense_update(6, 1, 1, vec![vec![0.0; 2]]),
        ];
        core.apply_updates(&a1, &ups, None).unwrap();
        assert_eq!(core.end_block(6), BlockOutcome::MidRound);
        let a2 = core.begin_block().unwrap();
        assert!(!a2.new_round, "mid-round block must not resample");
        assert_eq!(a2.k, 12);
    }
}
