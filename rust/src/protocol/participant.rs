//! The participant: a compute-owning shard of the client fleet.
//!
//! A `Participant` is the xaynet-style worker role: it owns a
//! `ComputeBackend`, its shard of `ClientState`s, the (deterministically
//! reconstructed) data partition and generator, and a local replica of the
//! global model that every `SyncDecision` keeps current.  It answers
//! `RoundAssignment`s by advancing its active clients `gap` local steps
//! (fanned across `runtime::cluster` worker threads) and emitting one
//! `LayerUpdate` per due group per active client; it never sees the
//! schedule, the ledger, or other participants' clients.
//!
//! The in-proc transport wraps a single participant owning every client;
//! the multi-process transport runs one per `fedlama worker` subprocess.
//! Either way the numeric stream is identical: client RNGs are keyed by
//! global client id, compression by (seed, k, group, client), and all
//! cross-client reductions happen on the coordinator.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::clients::ClientState;
use crate::comm::Spec;
use crate::config::chaos::FaultPlan;
use crate::config::{Algorithm, RunConfig};
use crate::data::{partition_for, ClientData, Generator, Partition};
use crate::runtime::{cluster, ComputeBackend, HostTensor};

use super::messages::{
    encode_tensor, update_stream_seed, LayerUpdate, RoundAssignment, SyncDecision,
};

pub struct Participant {
    pub worker_id: usize,
    cfg: RunConfig,
    backend: Arc<dyn ComputeBackend>,
    gen: Generator,
    pub partition: Partition,
    /// Global client ids this participant owns (sorted).
    shard: Vec<usize>,
    in_shard: Vec<bool>,
    /// Full-fleet indexing; non-shard slots hold placeholders.
    clients: Vec<ClientState>,
    /// Local replica of the global model (kept current by decisions).
    pub global: Vec<HostTensor>,
    /// SCAFFOLD server control variate (in-proc transport only).
    server_control: Option<Vec<HostTensor>>,
    compressor: Spec,
    compress_enabled: bool,
    /// Parsed `--chaos` plan; decides whether *this* shard mangles its
    /// uplinks (payload attacks are produced client-side, pre-compression,
    /// so they ride every transport identically).
    chaos: FaultPlan,
}

impl Participant {
    /// Build a participant owning `shard` (global client ids).  The
    /// partition, generator, initial global model, and client RNG streams
    /// are all derived from `cfg` — identical across every process that
    /// constructs from the same config.
    pub fn new(
        cfg: &RunConfig,
        backend: Arc<dyn ComputeBackend>,
        worker_id: usize,
        shard: Vec<usize>,
    ) -> Result<Participant> {
        let global = backend.init_params(cfg.seed as u32)?;
        let partition = partition_for(cfg);
        Self::with_state(cfg, backend, worker_id, shard, global, partition)
    }

    /// Like [`Participant::new`] but adopting an already-built initial
    /// global model and partition (the in-proc coordinator shares the ones
    /// it constructed for the core instead of deriving them twice).  Both
    /// MUST equal what `new` would derive from `cfg`.
    pub fn with_state(
        cfg: &RunConfig,
        backend: Arc<dyn ComputeBackend>,
        worker_id: usize,
        shard: Vec<usize>,
        global: Vec<HostTensor>,
        partition: Partition,
    ) -> Result<Participant> {
        let compressor = Spec::parse(&cfg.compressor)
            .ok_or_else(|| anyhow::anyhow!("unknown compressor {:?}", cfg.compressor))?;
        let chaos = FaultPlan::parse(&cfg.chaos)?;
        let mut in_shard = vec![false; cfg.n_clients];
        for &ci in &shard {
            anyhow::ensure!(ci < cfg.n_clients, "shard client {ci} >= n_clients");
            in_shard[ci] = true;
        }
        let clients = (0..cfg.n_clients)
            .map(|i| {
                if in_shard[i] {
                    ClientState::new(i, global.clone(), cfg.seed)
                } else {
                    ClientState::placeholder()
                }
            })
            .collect();
        let mut p = Participant {
            worker_id,
            gen: Generator::new(cfg.dataset, cfg.seed),
            partition,
            shard,
            in_shard,
            clients,
            global,
            server_control: None,
            compressor,
            compress_enabled: cfg.compressor != "dense",
            chaos,
            backend,
            cfg: cfg.clone(),
        };
        if p.cfg.resume_blocks > 0 {
            let blocks = p.cfg.resume_blocks;
            p.fast_forward(blocks)?;
        }
        Ok(p)
    }

    /// Checkpoint resume: advance the owned clients' data-rng streams past
    /// `blocks` already-committed training blocks without any model
    /// compute.  Replays exactly the draws `run_local_block` made in the
    /// interrupted run — per-round active sets (from a sampler replica
    /// seeded like the coordinator's), per-round budgets, and every
    /// per-example class/writer/feature draw — so each client rng (and its
    /// Box–Muller spare) lands bit-identically where the dead process left
    /// it.  Parameters are not touched: the caller refreshes the replica
    /// from the checkpointed global via catch-up decisions.  O(replayed
    /// examples) time, O(one example) extra memory.
    fn fast_forward(&mut self, blocks: usize) -> Result<()> {
        let b = self.backend.manifest().batch_size;
        let d: usize = self.backend.manifest().input_shape.iter().product();
        let gap = self.cfg.policy.base_interval();
        let round_len = self.cfg.policy.round_len();
        let blocks_per_round = (round_len / gap).max(1);
        let hetero = self.cfg.hetero_local_steps;
        let mean_n = self.partition.total as f64 / self.cfg.n_clients as f64;
        let mut sampler = crate::clients::ClientSampler::new(
            self.cfg.n_clients,
            self.cfg.active_ratio,
            self.cfg.seed,
        );
        let mut xbuf = vec![0.0f32; d];
        let mut mine: Vec<usize> = Vec::new();
        for blk in 0..blocks {
            if blk % blocks_per_round == 0 {
                let active = sampler.sample();
                mine = self.mine(&active);
                for &ci in &mine {
                    let frac = self.partition.clients[ci].total as f64 / mean_n;
                    let c = &mut self.clients[ci];
                    c.steps_in_round = 0;
                    c.local_budget = if hetero {
                        ((round_len as f64 * frac).round() as usize).clamp(1, round_len)
                    } else {
                        usize::MAX
                    };
                }
            }
            for &ci in &mine {
                let data = &self.partition.clients[ci];
                let c = &mut self.clients[ci];
                let steps = gap.min(c.local_budget.saturating_sub(c.steps_in_round));
                for _ in 0..steps * b {
                    let class = data.sample_class(&mut c.rng);
                    let writer = data.sample_writer(&mut c.rng);
                    self.gen.gen_example(class, writer, &mut c.rng, &mut xbuf);
                }
                c.steps_in_round += steps;
            }
        }
        Ok(())
    }

    pub fn shard(&self) -> &[usize] {
        &self.shard
    }

    pub fn clients(&self) -> &[ClientState] {
        &self.clients
    }

    pub fn backend(&self) -> &dyn ComputeBackend {
        self.backend.as_ref()
    }

    /// Cumulative compute seconds inside this participant's backend.
    pub fn compute_secs(&self) -> f64 {
        self.backend.stats_total_secs()
    }

    /// Worker threads the local-training fan-out will use (see
    /// `Coordinator::effective_threads`).
    pub fn effective_threads(&self) -> usize {
        if self.backend.as_parallel().is_none() {
            return 1;
        }
        if self.cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.cfg.threads
        }
    }

    /// The shard's members of an active set, in active order.
    fn mine(&self, active: &[usize]) -> Vec<usize> {
        active.iter().copied().filter(|&ci| self.in_shard[ci]).collect()
    }

    /// Handle one training block: returns ((client, mean loss) pairs in
    /// active order, layer updates for every due group x owned active
    /// client).
    pub fn handle_assignment(
        &mut self,
        a: &RoundAssignment,
    ) -> Result<(Vec<(usize, f64)>, Vec<LayerUpdate>)> {
        let mine = self.mine(&a.active);
        if a.new_round {
            self.begin_round(&mine);
        }
        let losses = self.run_local_block(&mine, a.gap, a.lr)?;
        let mut updates = Vec::with_capacity(a.due_groups.len() * mine.len());
        for &g in &a.due_groups {
            for &ci in &mine {
                updates.push(self.encode_update(a.k, a.round, g, ci));
            }
        }
        Ok((mine.iter().copied().zip(losses).collect(), updates))
    }

    /// Apply an aggregation decision: refresh the global replica and
    /// broadcast the new group params into the owned active clients.
    pub fn apply_decision(&mut self, d: &SyncDecision, active: &[usize]) -> Result<()> {
        let groups = &self.backend.manifest().groups;
        anyhow::ensure!(d.group < groups.len(), "decision for unknown group {}", d.group);
        let group = groups[d.group].clone();
        anyhow::ensure!(
            d.new_params.len() == group.params.len(),
            "decision for group {} carries {} tensors, expected {}",
            d.group,
            d.new_params.len(),
            group.params.len()
        );
        for (ti, &t) in group.params.iter().enumerate() {
            anyhow::ensure!(
                d.new_params[ti].len() == self.global[t].data.len(),
                "decision tensor {ti} length {} != {}",
                d.new_params[ti].len(),
                self.global[t].data.len()
            );
            self.global[t].data.copy_from_slice(&d.new_params[ti]);
            for &ci in active {
                if self.in_shard[ci] {
                    self.clients[ci].params[t].data.copy_from_slice(&d.new_params[ti]);
                }
            }
        }
        Ok(())
    }

    /// Round-start bookkeeping for the owned active clients: download the
    /// global replica, reset budgets, take algorithm-specific snapshots.
    fn begin_round(&mut self, mine: &[usize]) {
        let hetero = self.cfg.hetero_local_steps;
        let round_len = self.cfg.policy.round_len();
        let mean_n = self.partition.total as f64 / self.cfg.n_clients as f64;
        for &ci in mine {
            let need_ref = matches!(self.cfg.algorithm, Algorithm::Prox { .. } | Algorithm::Nova);
            let frac = self.partition.clients[ci].total as f64 / mean_n;
            let c = &mut self.clients[ci];
            c.pull(&self.global);
            c.steps_in_round = 0;
            c.local_budget = if hetero {
                ((round_len as f64 * frac).round() as usize).clamp(1, round_len)
            } else {
                usize::MAX
            };
            if need_ref {
                c.snapshot_round_start();
            }
            if self.cfg.algorithm == Algorithm::Scaffold && c.control.is_none() {
                c.control =
                    Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
            }
        }
        if self.cfg.algorithm == Algorithm::Scaffold && self.server_control.is_none() {
            self.server_control =
                Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
        }
    }

    /// Advance the owned active clients `gap` local steps via the cluster
    /// runtime (clients temporarily moved out for disjoint `&mut` access).
    /// Returns per-client mean losses in `mine` order (NaN = budget
    /// exhausted).
    fn run_local_block(&mut self, mine: &[usize], gap: usize, lr: f32) -> Result<Vec<f64>> {
        let mut moved: Vec<ClientState> = mine
            .iter()
            .map(|&ci| std::mem::replace(&mut self.clients[ci], ClientState::placeholder()))
            .collect();
        let parts: Vec<&ClientData> =
            mine.iter().map(|&ci| &self.partition.clients[ci]).collect();
        let ctx = cluster::StepCtx {
            gen: &self.gen,
            parts: &parts,
            algorithm: self.cfg.algorithm,
            server_control: self.server_control.as_deref(),
            gap,
            lr,
            use_chunk: self.cfg.use_chunk,
        };
        let result =
            cluster::advance(self.backend.as_ref(), &ctx, &mut moved, self.effective_threads());
        for (&ci, c) in mine.iter().zip(moved) {
            self.clients[ci] = c;
        }
        result
    }

    /// Produce one client's uplink for one group: copy its group tensors,
    /// apply any `--chaos` payload attack (then the configured lossy
    /// transform) on message-derived RNG streams, and wrap as payloads.
    /// Attacks mangle the raw tensors *before* compression, so an
    /// adversarial uplink is byte-identical on every transport.
    fn encode_update(&self, k: usize, round: usize, g: usize, ci: usize) -> LayerUpdate {
        let group = &self.backend.manifest().groups[g];
        let mut mangler =
            self.chaos.uplink_mangler(self.worker_id, round, self.cfg.seed, k, g, ci);
        let tensors = group
            .params
            .iter()
            .enumerate()
            .map(|(ti, &t)| {
                let mut buf = self.clients[ci].params[t].data.clone();
                if let Some(m) = mangler.as_mut() {
                    m.apply(&mut buf);
                }
                if self.compress_enabled {
                    // one stream per (message, tensor): transport-invariant
                    // and uncorrelated across the group's tensors
                    let seed = self.cfg.seed ^ (ti as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
                    let stream = update_stream_seed(seed, k, g, ci);
                    encode_tensor(self.compressor, stream, &mut buf)
                } else {
                    super::messages::Payload::Dense(buf)
                }
            })
            .collect();
        LayerUpdate { k, group: g, client: ci, tensors }
    }

    // -----------------------------------------------------------------------
    // Server-side-state baselines (in-proc transport only): these read or
    // reduce across client states, which the wire protocol does not ship.
    // -----------------------------------------------------------------------

    /// FedNova normalized averaging (Wang et al. 2020) over the owned
    /// clients — requires owning *all* active clients.  Mutates the global
    /// replica and pulls it into the active clients; returns the new
    /// global for the coordinator core to adopt.
    pub fn nova_aggregate(&mut self, active: &[usize]) -> Result<Vec<HostTensor>> {
        let weights = self.partition.active_weights(active);
        let tau_eff: f64 = active
            .iter()
            .zip(&weights)
            .map(|(&ci, &w)| w as f64 * self.clients[ci].steps_in_round as f64)
            .sum();
        for t in 0..self.global.len() {
            let len = self.global[t].data.len();
            let mut delta = vec![0.0f64; len];
            for (&ci, &w) in active.iter().zip(&weights) {
                let a_i = self.clients[ci].steps_in_round.max(1) as f64;
                let start = self.clients[ci]
                    .round_start
                    .as_ref()
                    .context("FedNova requires round_start")?;
                let x = &self.clients[ci].params[t].data;
                let s = &start[t].data;
                for j in 0..len {
                    delta[j] += w as f64 * (x[j] - s[j]) as f64 / a_i;
                }
            }
            let gdata = &mut self.global[t].data;
            for j in 0..len {
                gdata[j] += (tau_eff * delta[j]) as f32;
            }
        }
        for &ci in active {
            let global = std::mem::take(&mut self.global);
            self.clients[ci].pull(&global);
            self.global = global;
        }
        Ok(self.global.clone())
    }

    /// SCAFFOLD option-II control update (before aggregation):
    /// c_i+ = c_i - c + (x_start - x_i) / (a_i * lr);  c += sum dc_i / N.
    pub fn scaffold_update_controls(
        &mut self,
        active: &[usize],
        round_len: usize,
        lr: f32,
    ) -> Result<()> {
        let n = self.cfg.n_clients as f32;
        let server = self.server_control.as_mut().context("server control")?;
        for &ci in active {
            let a_i = self.clients[ci].steps_in_round.max(1).min(round_len) as f32;
            let scale = 1.0 / (a_i * lr);
            let client = &mut self.clients[ci];
            let control = client.control.as_mut().context("client control")?;
            for t in 0..control.len() {
                let x = &client.params[t].data;
                let g = &self.global[t].data; // x_start == global at round start
                let c_t = &mut control[t].data;
                let s_t = &mut server[t].data;
                for j in 0..c_t.len() {
                    let c_new = c_t[j] - s_t[j] + scale * (g[j] - x[j]);
                    let dc = c_new - c_t[j];
                    c_t[j] = c_new;
                    s_t[j] += dc / n;
                }
            }
        }
        Ok(())
    }
}
