//! The participant: a compute-owning shard of the client fleet.
//!
//! A `Participant` is the xaynet-style worker role: it owns a
//! `ComputeBackend`, its shard of `ClientState`s, the (deterministically
//! reconstructed) data partition and generator, and a local replica of the
//! global model that every `SyncDecision` keeps current.  It answers
//! `RoundAssignment`s by advancing its active clients `gap` local steps
//! (fanned across `runtime::cluster` worker threads) and emitting one
//! `LayerUpdate` per due group per active client; it never sees the
//! schedule, the ledger, or other participants' clients.
//!
//! The in-proc transport wraps a single participant owning every client;
//! the multi-process transport runs one per `fedlama worker` subprocess.
//! Either way the numeric stream is identical: client RNGs are keyed by
//! global client id, compression by (seed, k, group, client), and all
//! cross-client reductions happen on the coordinator.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::clients::ClientState;
use crate::comm::Spec;
use crate::config::chaos::FaultPlan;
use crate::config::{Algorithm, RunConfig};
use crate::data::{partition_for, ClientData, Generator, Partition};
use crate::runtime::{cluster, ComputeBackend, HostTensor};

use super::messages::{
    encode_tensor, update_stream_seed, AlgoState, ControlUpdate, LayerUpdate, RoundAssignment,
    SyncDecision,
};

pub struct Participant {
    pub worker_id: usize,
    cfg: RunConfig,
    backend: Arc<dyn ComputeBackend>,
    gen: Generator,
    pub partition: Partition,
    /// Global client ids this participant owns (sorted).
    shard: Vec<usize>,
    in_shard: Vec<bool>,
    /// Full-fleet indexing; non-shard slots hold placeholders.
    clients: Vec<ClientState>,
    /// Local replica of the global model (kept current by decisions).
    pub global: Vec<HostTensor>,
    /// SCAFFOLD server control variate — a local replica kept current by
    /// `ControlUpdate` broadcasts from the coordinator (the authoritative
    /// fold lives in `CoordinatorCore::scaffold_fold`).
    server_control: Option<Vec<HostTensor>>,
    /// Personalized policy: which owned clients already hold their
    /// personalized params (round starts stop overwriting them with the
    /// global replica once they do).
    personal_init: Vec<bool>,
    compressor: Spec,
    compress_enabled: bool,
    /// Parsed `--chaos` plan; decides whether *this* shard mangles its
    /// uplinks (payload attacks are produced client-side, pre-compression,
    /// so they ride every transport identically).
    chaos: FaultPlan,
}

impl Participant {
    /// Build a participant owning `shard` (global client ids).  The
    /// partition, generator, initial global model, and client RNG streams
    /// are all derived from `cfg` — identical across every process that
    /// constructs from the same config.
    pub fn new(
        cfg: &RunConfig,
        backend: Arc<dyn ComputeBackend>,
        worker_id: usize,
        shard: Vec<usize>,
    ) -> Result<Participant> {
        let global = backend.init_params(cfg.seed as u32)?;
        let partition = partition_for(cfg);
        Self::with_state(cfg, backend, worker_id, shard, global, partition)
    }

    /// Like [`Participant::new`] but adopting an already-built initial
    /// global model and partition (the in-proc coordinator shares the ones
    /// it constructed for the core instead of deriving them twice).  Both
    /// MUST equal what `new` would derive from `cfg`.
    pub fn with_state(
        cfg: &RunConfig,
        backend: Arc<dyn ComputeBackend>,
        worker_id: usize,
        shard: Vec<usize>,
        global: Vec<HostTensor>,
        partition: Partition,
    ) -> Result<Participant> {
        let compressor = Spec::parse(&cfg.compressor)
            .ok_or_else(|| anyhow::anyhow!("unknown compressor {:?}", cfg.compressor))?;
        let chaos = FaultPlan::parse(&cfg.chaos)?;
        let mut in_shard = vec![false; cfg.n_clients];
        for &ci in &shard {
            anyhow::ensure!(ci < cfg.n_clients, "shard client {ci} >= n_clients");
            in_shard[ci] = true;
        }
        let clients = (0..cfg.n_clients)
            .map(|i| {
                if in_shard[i] {
                    ClientState::new(i, global.clone(), cfg.seed)
                } else {
                    ClientState::placeholder()
                }
            })
            .collect();
        let mut p = Participant {
            worker_id,
            gen: Generator::new(cfg.dataset, cfg.seed),
            partition,
            shard,
            in_shard,
            clients,
            global,
            server_control: None,
            personal_init: vec![false; cfg.n_clients],
            compressor,
            compress_enabled: cfg.compressor != "dense",
            chaos,
            backend,
            cfg: cfg.clone(),
        };
        if p.cfg.resume_blocks > 0 {
            let blocks = p.cfg.resume_blocks;
            p.fast_forward(blocks)?;
        }
        Ok(p)
    }

    /// Checkpoint resume: advance the owned clients' data-rng streams past
    /// `blocks` already-committed training blocks without any model
    /// compute.  Replays exactly the draws `run_local_block` made in the
    /// interrupted run — per-round active sets (from a sampler replica
    /// seeded like the coordinator's), per-round budgets, and every
    /// per-example class/writer/feature draw — so each client rng (and its
    /// Box–Muller spare) lands bit-identically where the dead process left
    /// it.  Parameters are not touched: the caller refreshes the replica
    /// from the checkpointed global via catch-up decisions.  O(replayed
    /// examples) time, O(one example) extra memory.
    fn fast_forward(&mut self, blocks: usize) -> Result<()> {
        let b = self.backend.manifest().batch_size;
        let d: usize = self.backend.manifest().input_shape.iter().product();
        let gap = self.cfg.policy.base_interval();
        let round_len = self.cfg.policy.round_len();
        let blocks_per_round = (round_len / gap).max(1);
        let hetero = self.cfg.hetero_local_steps;
        let mean_n = self.partition.total as f64 / self.cfg.n_clients as f64;
        let mut sampler = crate::clients::ClientSampler::new(
            self.cfg.n_clients,
            self.cfg.active_ratio,
            self.cfg.seed,
        );
        let mut xbuf = vec![0.0f32; d];
        let mut mine: Vec<usize> = Vec::new();
        for blk in 0..blocks {
            if blk % blocks_per_round == 0 {
                let active = sampler.sample();
                mine = self.mine(&active);
                for &ci in &mine {
                    let frac = self.partition.clients[ci].total as f64 / mean_n;
                    let c = &mut self.clients[ci];
                    c.steps_in_round = 0;
                    c.local_budget = if hetero {
                        ((round_len as f64 * frac).round() as usize).clamp(1, round_len)
                    } else {
                        usize::MAX
                    };
                }
            }
            for &ci in &mine {
                let data = &self.partition.clients[ci];
                let c = &mut self.clients[ci];
                let steps = gap.min(c.local_budget.saturating_sub(c.steps_in_round));
                for _ in 0..steps * b {
                    let class = data.sample_class(&mut c.rng);
                    let writer = data.sample_writer(&mut c.rng);
                    self.gen.gen_example(class, writer, &mut c.rng, &mut xbuf);
                }
                c.steps_in_round += steps;
            }
        }
        Ok(())
    }

    pub fn shard(&self) -> &[usize] {
        &self.shard
    }

    pub fn clients(&self) -> &[ClientState] {
        &self.clients
    }

    pub fn backend(&self) -> &dyn ComputeBackend {
        self.backend.as_ref()
    }

    /// Cumulative compute seconds inside this participant's backend.
    pub fn compute_secs(&self) -> f64 {
        self.backend.stats_total_secs()
    }

    /// Worker threads the local-training fan-out will use (see
    /// `Coordinator::effective_threads`).
    pub fn effective_threads(&self) -> usize {
        if self.backend.as_parallel().is_none() {
            return 1;
        }
        if self.cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.cfg.threads
        }
    }

    /// The shard's members of an active set, in active order.
    fn mine(&self, active: &[usize]) -> Vec<usize> {
        active.iter().copied().filter(|&ci| self.in_shard[ci]).collect()
    }

    /// Handle one training block: returns ((client, mean loss) pairs in
    /// active order, layer updates for every due group x owned active
    /// client, and — at round boundaries under SCAFFOLD/FedNova — one
    /// [`AlgoState`] per owned active client carrying the state the
    /// coordinator's server-side fold needs.
    pub fn handle_assignment(
        &mut self,
        a: &RoundAssignment,
    ) -> Result<(Vec<(usize, f64)>, Vec<LayerUpdate>, Vec<AlgoState>)> {
        let mine = self.mine(&a.active);
        if a.new_round {
            self.begin_round(&mine);
        }
        let losses = self.run_local_block(&mine, a.gap, a.lr)?;
        let mut updates = Vec::with_capacity(a.due_groups.len() * mine.len());
        for &g in &a.due_groups {
            for &ci in &mine {
                updates.push(self.encode_update(a.k, a.round, g, ci));
            }
        }
        let algo = if a.k % self.cfg.policy.round_len() == 0 {
            self.round_end_algo_states(a.k, &mine, a.lr)?
        } else {
            Vec::new()
        };
        Ok((mine.iter().copied().zip(losses).collect(), updates, algo))
    }

    /// Apply an aggregation decision: refresh the global replica and
    /// broadcast the new group params into the owned active clients.
    pub fn apply_decision(&mut self, d: &SyncDecision, active: &[usize]) -> Result<()> {
        let groups = &self.backend.manifest().groups;
        anyhow::ensure!(d.group < groups.len(), "decision for unknown group {}", d.group);
        let group = groups[d.group].clone();
        anyhow::ensure!(
            d.new_params.len() == group.params.len(),
            "decision for group {} carries {} tensors, expected {}",
            d.group,
            d.new_params.len(),
            group.params.len()
        );
        for (ti, &t) in group.params.iter().enumerate() {
            anyhow::ensure!(
                d.new_params[ti].len() == self.global[t].data.len(),
                "decision tensor {ti} length {} != {}",
                d.new_params[ti].len(),
                self.global[t].data.len()
            );
            self.global[t].data.copy_from_slice(&d.new_params[ti]);
            for &ci in active {
                if !self.in_shard[ci] {
                    continue;
                }
                match d.mix_for(ci) {
                    // pFedLA-style blend: the client keeps (1 - lambda) of
                    // its own params, taking lambda of the aggregate.
                    Some(lam) => {
                        let x = &mut self.clients[ci].params[t].data;
                        for (xj, &uj) in x.iter_mut().zip(&d.new_params[ti]) {
                            *xj = lam * uj + (1.0 - lam) * *xj;
                        }
                    }
                    None => {
                        self.clients[ci].params[t].data.copy_from_slice(&d.new_params[ti]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Round-start bookkeeping for the owned active clients: download the
    /// global replica, reset budgets, take algorithm-specific snapshots.
    fn begin_round(&mut self, mine: &[usize]) {
        let hetero = self.cfg.hetero_local_steps;
        let round_len = self.cfg.policy.round_len();
        let mean_n = self.partition.total as f64 / self.cfg.n_clients as f64;
        let personalizing = self.cfg.policy.mix_eta().is_some();
        for &ci in mine {
            let need_ref = matches!(self.cfg.algorithm, Algorithm::Prox { .. } | Algorithm::Nova);
            let frac = self.partition.clients[ci].total as f64 / mean_n;
            // Personalized policy: a client that already holds its
            // personalized params keeps them across rounds — only its
            // *first* activation downloads the global model.  Every other
            // policy re-downloads at each round start.
            let pull = !personalizing || !self.personal_init[ci];
            self.personal_init[ci] = true;
            let c = &mut self.clients[ci];
            if pull {
                c.pull(&self.global);
            }
            c.steps_in_round = 0;
            c.local_budget = if hetero {
                ((round_len as f64 * frac).round() as usize).clamp(1, round_len)
            } else {
                usize::MAX
            };
            if need_ref {
                c.snapshot_round_start();
            }
            if self.cfg.algorithm == Algorithm::Scaffold && c.control.is_none() {
                c.control =
                    Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
            }
        }
        if self.cfg.algorithm == Algorithm::Scaffold && self.server_control.is_none() {
            self.server_control =
                Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
        }
    }

    /// Advance the owned active clients `gap` local steps via the cluster
    /// runtime (clients temporarily moved out for disjoint `&mut` access).
    /// Returns per-client mean losses in `mine` order (NaN = budget
    /// exhausted).
    fn run_local_block(&mut self, mine: &[usize], gap: usize, lr: f32) -> Result<Vec<f64>> {
        let mut moved: Vec<ClientState> = mine
            .iter()
            .map(|&ci| std::mem::replace(&mut self.clients[ci], ClientState::placeholder()))
            .collect();
        let parts: Vec<&ClientData> =
            mine.iter().map(|&ci| &self.partition.clients[ci]).collect();
        let ctx = cluster::StepCtx {
            gen: &self.gen,
            parts: &parts,
            algorithm: self.cfg.algorithm,
            server_control: self.server_control.as_deref(),
            gap,
            lr,
            use_chunk: self.cfg.use_chunk,
        };
        let result =
            cluster::advance(self.backend.as_ref(), &ctx, &mut moved, self.effective_threads());
        for (&ci, c) in mine.iter().zip(moved) {
            self.clients[ci] = c;
        }
        result
    }

    /// Produce one client's uplink for one group: copy its group tensors,
    /// apply any `--chaos` payload attack (then the configured lossy
    /// transform) on message-derived RNG streams, and wrap as payloads.
    /// Attacks mangle the raw tensors *before* compression, so an
    /// adversarial uplink is byte-identical on every transport.
    fn encode_update(&self, k: usize, round: usize, g: usize, ci: usize) -> LayerUpdate {
        let group = &self.backend.manifest().groups[g];
        let mut mangler =
            self.chaos.uplink_mangler(self.worker_id, round, self.cfg.seed, k, g, ci);
        let tensors = group
            .params
            .iter()
            .enumerate()
            .map(|(ti, &t)| {
                let mut buf = self.clients[ci].params[t].data.clone();
                if let Some(m) = mangler.as_mut() {
                    m.apply(&mut buf);
                }
                if self.compress_enabled {
                    // one stream per (message, tensor): transport-invariant
                    // and uncorrelated across the group's tensors
                    let seed = self.cfg.seed ^ (ti as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
                    let stream = update_stream_seed(seed, k, g, ci);
                    encode_tensor(self.compressor, stream, &mut buf)
                } else {
                    super::messages::Payload::Dense(buf)
                }
            })
            .collect();
        LayerUpdate { k, group: g, client: ci, tensors }
    }

    // -----------------------------------------------------------------------
    // Server-side-state baselines over the wire: each owned active client's
    // round-end algorithm state ships to the coordinator as an `AlgoState`
    // frame; the cross-client folds live in `CoordinatorCore` and their
    // results come back as `SyncDecision`/`ControlUpdate` broadcasts.  All
    // per-client math here is f32 and local to one client, so the bytes on
    // the wire are identical on every transport.
    // -----------------------------------------------------------------------

    /// Produce the round-end `AlgoState` for every owned active client.
    ///
    /// FedNova ships the raw round delta `x_i - x_start` plus the local
    /// step count (the coordinator computes tau_eff and the normalized
    /// fold).  SCAFFOLD performs the option-II control refresh locally —
    /// `c_i+ = c_i - c + (x_start - x_i) / (a_i * lr)` against the
    /// round-start server control replica — adopts `c_i+`, and ships it
    /// (the coordinator folds `c += sum (c_i+ - c_i) / N` from its
    /// registry-spilled copy of the previous `c_i`).
    fn round_end_algo_states(
        &mut self,
        k: usize,
        mine: &[usize],
        lr: f32,
    ) -> Result<Vec<AlgoState>> {
        let round_len = self.cfg.policy.round_len();
        let mut out = Vec::new();
        match self.cfg.algorithm {
            Algorithm::Nova => {
                for &ci in mine {
                    let client = &self.clients[ci];
                    let start = client
                        .round_start
                        .as_ref()
                        .context("FedNova requires round_start")?;
                    let tensors: Vec<Vec<f32>> = client
                        .params
                        .iter()
                        .zip(start)
                        .map(|(x, s)| {
                            x.data.iter().zip(&s.data).map(|(&xj, &sj)| xj - sj).collect()
                        })
                        .collect();
                    out.push(AlgoState {
                        k,
                        client: ci,
                        steps: client.steps_in_round as u64,
                        tensors,
                    });
                }
            }
            Algorithm::Scaffold => {
                let server = self.server_control.as_ref().context("server control")?;
                for &ci in mine {
                    let client = &mut self.clients[ci];
                    let a_i = client.steps_in_round.max(1).min(round_len) as f32;
                    let scale = 1.0 / (a_i * lr);
                    let control = client.control.as_mut().context("client control")?;
                    let mut tensors = Vec::with_capacity(control.len());
                    for t in 0..control.len() {
                        let x = &client.params[t].data;
                        let g = &self.global[t].data; // x_start == global at round start
                        let c_t = &mut control[t].data;
                        let s_t = &server[t].data;
                        for j in 0..c_t.len() {
                            c_t[j] = c_t[j] - s_t[j] + scale * (g[j] - x[j]);
                        }
                        tensors.push(c_t.clone());
                    }
                    out.push(AlgoState {
                        k,
                        client: ci,
                        steps: client.steps_in_round as u64,
                        tensors,
                    });
                }
            }
            _ => {}
        }
        Ok(out)
    }

    /// Adopt a broadcast server control variate (SCAFFOLD `c`), replacing
    /// the local replica.  Shapes follow the global model.
    pub fn set_server_control(&mut self, c: &ControlUpdate) -> Result<()> {
        anyhow::ensure!(
            c.tensors.len() == self.global.len(),
            "control update carries {} tensors, model has {}",
            c.tensors.len(),
            self.global.len()
        );
        let tensors = self
            .global
            .iter()
            .zip(&c.tensors)
            .map(|(g, data)| {
                anyhow::ensure!(
                    data.len() == g.data.len(),
                    "control tensor length {} != {}",
                    data.len(),
                    g.data.len()
                );
                Ok(HostTensor { shape: g.shape.clone(), data: data.clone() })
            })
            .collect::<Result<Vec<_>>>()?;
        self.server_control = Some(tensors);
        Ok(())
    }

    /// Adopt one client's control variate from a catchup `AlgoState`
    /// (rejoin/resume: the coordinator replays registry-spilled `c_i` so a
    /// fresh participant's clients resume where the run left off).
    pub fn adopt_algo_state(&mut self, a: &AlgoState) -> Result<()> {
        anyhow::ensure!(a.client < self.cfg.n_clients, "algo state for unknown client");
        if !self.in_shard[a.client] {
            return Ok(());
        }
        anyhow::ensure!(
            a.tensors.len() == self.global.len(),
            "algo state carries {} tensors, model has {}",
            a.tensors.len(),
            self.global.len()
        );
        let tensors = self
            .global
            .iter()
            .zip(&a.tensors)
            .map(|(g, data)| {
                anyhow::ensure!(
                    data.len() == g.data.len(),
                    "algo tensor length {} != {}",
                    data.len(),
                    g.data.len()
                );
                Ok(HostTensor { shape: g.shape.clone(), data: data.clone() })
            })
            .collect::<Result<Vec<_>>>()?;
        self.clients[a.client].control = Some(tensors);
        Ok(())
    }
}
