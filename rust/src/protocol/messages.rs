//! Typed federation-protocol messages and their wire schemas.
//!
//! The protocol has two roles.  The **coordinator** owns Algorithm 1's
//! server state (schedule, ledger, sampler, global params) and never runs
//! model compute; **participants** own client shards and a compute backend
//! and never make scheduling decisions.  One training block exchanges:
//!
//! ```text
//!   coordinator                                participant(s)
//!        | -- RoundAssignment {k, active, lr, gap, due} -->
//!        |                       (train shard ∩ active for gap steps)
//!        | <-- LayerUpdate {k, group, client, tensors} -- (per due group/client)
//!        | <-- BlockDone {losses, compute_secs} --------
//!        |  (aggregate rows per group, observe d_l, charge Eq. 9 ledger)
//!        | -- SyncDecision {k, group, new_params, new_interval} -->
//! ```
//!
//! plus a session handshake (`Configure` -> `Hello`), liveness
//! (`Heartbeat`), and `Shutdown`.
//!
//! `LayerUpdate` tensors travel as [`Payload`]s: dense f32, q-bit
//! quantized, or top-k sparse — mirroring `comm::compression`.  The lossy
//! *values* a payload decodes to are exactly (bit-for-bit) the values the
//! compressor produced on the participant, so aggregation is independent
//! of which transport carried the update.  The `nominal_bytes` of a
//! payload is the byte count the simulation ledger charges (the
//! compressor's idealized encoded size); the wire framing itself is
//! faithful but not maximally bit-packed, and is never what Eq. 9 reports.
//!
//! # Streamed per-layer framing (wire v2)
//!
//! The bulk messages have two wire representations.  The *monolithic*
//! frames (`Update` kind 5, `Decision` kind 7) carry a whole message in
//! one frame and remain fully supported — they are the v1 compatibility
//! shim.  The *streamed* representation splits a message into a `Begin`
//! frame (metadata + tensor count) followed by one frame per tensor
//! (`seq` + payload), so peak encode staging and first-byte latency
//! scale with one *layer*, not the whole update:
//!
//! ```text
//!   UpdateBegin   {k, group, client, n_tensors}        kind 10
//!   UpdateTensor  {seq, payload}      x n_tensors      kind 11
//!   DecisionBegin {k, group, new_interval, n_tensors}  kind 12
//!   DecisionTensor{seq, f32s}         x n_tensors      kind 13
//!   AlgoBegin     {k, client, steps, n_tensors}        kind 16
//!   AlgoTensor    {seq, f32s}         x n_tensors      kind 17
//!   ControlBegin  {k, n_tensors}                       kind 18
//!   ControlTensor {seq, f32s}         x n_tensors      kind 19
//! ```
//!
//! [`AlgoState`] (kinds 14/16/17) and [`ControlUpdate`] (kinds 15/18/19)
//! carry the server-side-algorithm reductions that used to live in-proc
//! only: SCAFFOLD ships each owned client's refreshed control variate up
//! and the server control `s_t` back down; FedNova ships each client's
//! raw round delta + step count up for the normalized server fold.  Both
//! travel as raw f32 bit patterns (never compressed — algorithm state is
//! exact), so the reductions are bit-identical on every transport.
//!
//! [`Message::write_streamed`] emits tensor frames through
//! `wire::write_frame_gather`, borrowing tensor storage (zero-copy on
//! little-endian) with the CRC computed incrementally.  [`Assembler`]
//! reassembles the sequence on the receive side — [`Heartbeat`] frames
//! pass through mid-assembly (liveness never waits on a large update),
//! any other interleaved kind is an error — and [`MessageStream`] pairs
//! it with `wire::StreamDecoder` for non-blocking socket receive paths.
//! Reassembly is per-connection, so a corrupt tensor frame fails exactly
//! one peer's stream; and because the coordinator still stages complete
//! `LayerUpdate` rows before the commit fold, fold order stays
//! shard-then-layer, never arrival order — streamed runs are
//! bit-identical to monolithic ones on every transport.

use anyhow::{bail, ensure, Result};

use crate::aggregation::Policy;
use crate::comm::{Compressor, Quantizer, Spec, TopK};
use crate::config::{Algorithm, EngineKind, PartitionKind, RunConfig};
use crate::data::DatasetKind;
use crate::runtime::simd::{self, Isa};

use super::wire::{self, Dec, Enc, Gather, StreamDecoder};

// ---------------------------------------------------------------------------
// Payload: one tensor on the wire
// ---------------------------------------------------------------------------

/// A flattened tensor in one of the protocol's encodings.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Raw f32 values.
    Dense(Vec<f32>),
    /// QSGD-style per-chunk uniform quantization: sign + `bits`-bit level
    /// per value, one f32 scale per `chunk` values.  Decodes to exactly the
    /// lossy values `comm::Quantizer` produced.
    QBits { bits: u8, chunk: u32, n: u32, scales: Vec<f32>, levels: Vec<u16>, signs: Vec<u8> },
    /// Top-k sparsification: kept (index, value) pairs, zeros elsewhere.
    /// `nominal` preserves the compressor's reported encoded size (which
    /// counts kept *slots*, including exact zeros the scan retained).
    TopK { n: u32, nominal: u32, indices: Vec<u32>, values: Vec<f32> },
}

impl Payload {
    /// Element count of the decoded tensor.
    pub fn len(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::QBits { n, .. } => *n as usize,
            Payload::TopK { n, .. } => *n as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The idealized encoded size in bytes — what the Eq. 9 byte ledger
    /// charges per uplink.  Matches `comm::compression`'s accounting:
    /// dense 4B/value; q-bit `bits` bits/value + one f32 scale per chunk;
    /// top-k 8B per kept slot.
    pub fn nominal_bytes(&self) -> usize {
        match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::QBits { bits, chunk, n, .. } => {
                let n = *n as usize;
                (n * *bits as usize).div_ceil(8) + n.div_ceil(*chunk as usize) * 4
            }
            Payload::TopK { nominal, .. } => *nominal as usize,
        }
    }

    /// Borrow the values directly when the payload is dense.
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            Payload::Dense(v) => Some(v),
            _ => None,
        }
    }

    /// Decode to dense f32 values.  For lossy encodings this reconstructs
    /// bit-for-bit the values the participant-side compressor produced.
    pub fn decode(&self) -> Result<Vec<f32>> {
        self.decode_with_isa(simd::active_isa())
    }

    /// [`Payload::decode`] with an explicit dispatch path.  Every `isa`
    /// produces bit-identical output (oracle-tested in
    /// `tests/simd_quant.rs`); benches and tests use this to pin a path.
    pub fn decode_with_isa(&self, isa: Isa) -> Result<Vec<f32>> {
        match self {
            Payload::Dense(v) => Ok(v.clone()),
            Payload::QBits { bits, chunk, n, scales, levels, signs } => {
                let n = *n as usize;
                let chunk = (*chunk as usize).max(1);
                ensure!(levels.len() == n, "qbits level count {} != n {n}", levels.len());
                ensure!(
                    scales.len() == n.div_ceil(chunk),
                    "qbits scale count {} != {}",
                    scales.len(),
                    n.div_ceil(chunk)
                );
                ensure!(signs.len() == n.div_ceil(8), "qbits sign bitmap length");
                ensure!((1..=16).contains(bits), "qbits bits {bits} out of range");
                let denom = ((1u32 << *bits) - 1) as f32;
                let mut out = vec![0.0f32; n];
                for (ci, ochunk) in out.chunks_mut(chunk).enumerate() {
                    let max = scales[ci];
                    let base = ci * chunk;
                    for (j, o) in ochunk.iter_mut().enumerate() {
                        *o = levels[base + j] as f32;
                    }
                    // exact mirror of Quantizer: v = sign * q / levels * max.
                    // q/denom*max is the same two IEEE ops per element on
                    // every dispatch path, so results stay bit-identical...
                    simd::div_mul(isa, ochunk, denom, max);
                    // ...and the negation is applied last (exact in IEEE-754)
                    for (j, o) in ochunk.iter_mut().enumerate() {
                        let i = base + j;
                        if ((signs[i / 8] >> (i % 8)) & 1) == 1 {
                            *o = -*o;
                        }
                    }
                }
                Ok(out)
            }
            Payload::TopK { n, indices, values, .. } => {
                ensure!(indices.len() == values.len(), "topk index/value length mismatch");
                let n = *n as usize;
                let mut out = vec![0.0f32; n];
                for (&i, &v) in indices.iter().zip(values) {
                    ensure!((i as usize) < n, "topk index {i} out of range {n}");
                    out[i as usize] = v;
                }
                Ok(out)
            }
        }
    }

    /// Re-encode the lossy output of `comm::Quantizer` (per-chunk scale
    /// recovered from the data itself — the chunk maximum survives
    /// quantization exactly).
    pub fn qbits_from(lossy: &[f32], bits: u32, chunk: usize) -> Payload {
        let denom = ((1u32 << bits) - 1) as f32;
        let n = lossy.len();
        let mut scales = Vec::with_capacity(n.div_ceil(chunk.max(1)));
        let mut levels = vec![0u16; n];
        let mut signs = vec![0u8; n.div_ceil(8)];
        for (c, vals) in lossy.chunks(chunk.max(1)).enumerate() {
            let max = vals.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            scales.push(max);
            for (j, &v) in vals.iter().enumerate() {
                let i = c * chunk.max(1) + j;
                if v.is_sign_negative() {
                    signs[i / 8] |= 1 << (i % 8);
                }
                if max > 0.0 {
                    // |v| = q/denom*max exactly, so the ratio recovers q to
                    // well under half a level for bits <= 16.
                    levels[i] = (v.abs() / max * denom).round() as u16;
                }
            }
        }
        Payload::QBits { bits: bits as u8, chunk: chunk as u32, n: n as u32, scales, levels, signs }
    }

    /// Re-encode the lossy output of `comm::TopK` (nonzero scatter), with
    /// the compressor's reported encoded size preserved for the ledger.
    pub fn topk_from(lossy: &[f32], nominal: usize) -> Payload {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in lossy.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        Payload::TopK { n: lossy.len() as u32, nominal: nominal as u32, indices, values }
    }

    fn encode(&self, e: &mut Enc) -> Result<()> {
        match self {
            Payload::Dense(v) => {
                e.u8(0);
                e.f32s(v)?;
            }
            Payload::QBits { bits, chunk, n, scales, levels, signs } => {
                e.u8(1);
                e.u8(*bits);
                e.u32(*chunk);
                e.u32(*n);
                e.f32s(scales)?;
                e.u16s(levels)?;
                e.bytes(signs)?;
            }
            Payload::TopK { n, nominal, indices, values } => {
                e.u8(2);
                e.u32(*n);
                e.u32(*nominal);
                e.u32s(indices)?;
                e.f32s(values)?;
            }
        }
        Ok(())
    }

    /// Scatter-gather twin of [`Payload::encode`]: identical wire bytes,
    /// but the bulk sequences (values, scales, levels, signs, indices)
    /// are *borrowed* into the gather instead of copied, so encoding a
    /// tensor stages only its tags and length prefixes.
    fn encode_gather<'a>(&'a self, g: &mut Gather<'a>) -> Result<()> {
        match self {
            Payload::Dense(v) => {
                g.u8(0);
                g.f32s(v)?;
            }
            Payload::QBits { bits, chunk, n, scales, levels, signs } => {
                g.u8(1);
                g.u8(*bits);
                g.u32(*chunk);
                g.u32(*n);
                g.f32s(scales)?;
                g.u16s(levels)?;
                g.bytes(signs)?;
            }
            Payload::TopK { n, nominal, indices, values } => {
                g.u8(2);
                g.u32(*n);
                g.u32(*nominal);
                g.u32s(indices)?;
                g.f32s(values)?;
            }
        }
        Ok(())
    }

    fn decode_wire(d: &mut Dec<'_>) -> Result<Payload> {
        Ok(match d.u8()? {
            0 => Payload::Dense(d.f32s()?),
            1 => Payload::QBits {
                bits: d.u8()?,
                chunk: d.u32()?,
                n: d.u32()?,
                scales: d.f32s()?,
                levels: d.u16s()?,
                signs: d.bytes()?,
            },
            2 => Payload::TopK {
                n: d.u32()?,
                nominal: d.u32()?,
                indices: d.u32s()?,
                values: d.f32s()?,
            },
            t => bail!("unknown payload tag {t}"),
        })
    }
}

/// Deterministic per-message compression stream: mixes (seed, k, group,
/// client) so the lossy transform of one uplink depends only on *what* is
/// being sent, never on which process sends it or in which order —
/// the property that makes compressed runs transport-invariant.
pub fn update_stream_seed(seed: u64, k: usize, group: usize, client: usize) -> u64 {
    let mut z = seed
        ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (group as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (client as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    // splitmix64 finalizer
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Compress one tensor according to `spec` on the message-derived stream
/// and wrap the result as a wire payload.  `data` is transformed in place
/// to the lossy values (the participant keeps training on its own exact
/// params; this buffer is the copy being "sent").
pub fn encode_tensor(spec: Spec, stream_seed: u64, data: &mut [f32]) -> Payload {
    match spec {
        Spec::Dense => Payload::Dense(data.to_vec()),
        Spec::QBits { bits } => {
            let mut q = Quantizer::new(bits, stream_seed);
            q.compress(data);
            Payload::qbits_from(data, bits, q.chunk)
        }
        Spec::TopK { ratio } => {
            let mut t = TopK::new(ratio);
            let nominal = t.compress(data);
            Payload::topk_from(data, nominal)
        }
    }
}

// ---------------------------------------------------------------------------
// Message structs
// ---------------------------------------------------------------------------

/// Worker -> coordinator: join handshake after `Configure`.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    pub version: u8,
    pub worker_id: usize,
    pub shard_len: usize,
}

/// Liveness probe; the receiver echoes the nonce back.
#[derive(Debug, Clone, PartialEq)]
pub struct Heartbeat {
    pub nonce: u64,
}

/// Coordinator -> worker: session setup.  Carries the run config subset a
/// participant needs to deterministically rebuild its backend, data
/// partition, and client shard — heavy state (datasets, partitions) is
/// reconstructed from the seed, never shipped.
#[derive(Debug, Clone, PartialEq)]
pub struct Configure {
    pub worker_id: usize,
    pub n_workers: usize,
    pub shard: Vec<usize>,
    pub cfg: RunConfig,
}

/// Coordinator -> participants: one training block.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundAssignment {
    /// Iteration index at the *end* of this block (1-based, Algorithm 1's k).
    pub k: usize,
    /// Round this block belongs to (0-based while in flight).
    pub round: usize,
    /// Local iterations to advance (the base interval gap).
    pub gap: usize,
    /// Learning rate for the block (warmup-adjusted).
    pub lr: f32,
    /// True when this block starts a round: participants re-pull the
    /// global model into newly active clients and reset budgets.
    pub new_round: bool,
    /// Active client ids this round (sorted, global numbering).
    pub active: Vec<usize>,
    /// Groups due for aggregation at k; participants upload these.
    pub due_groups: Vec<usize>,
}

/// Participant -> coordinator: one client's tensors for one due group.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerUpdate {
    pub k: usize,
    pub group: usize,
    pub client: usize,
    /// One payload per tensor of the group, in manifest `params` order.
    pub tensors: Vec<Payload>,
}

/// Participant -> coordinator: end of its part of a block.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockDone {
    pub worker_id: usize,
    pub k: usize,
    /// (client id, mean local loss) for the shard's active clients, in
    /// active order.  NaN = heterogeneous budget exhausted (as in-proc).
    pub losses: Vec<(usize, f64)>,
    /// Cumulative compute seconds inside the worker's backend (for the
    /// runtime utilization report).
    pub compute_secs: f64,
}

/// Coordinator -> participants: aggregated layer + next interval.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncDecision {
    pub k: usize,
    pub group: usize,
    /// The group's re-adjusted interval tau_l (informational for
    /// participants; due groups always arrive via assignments).
    pub new_interval: usize,
    /// Aggregated tensors u_l, dense, in manifest `params` order.
    pub new_params: Vec<Vec<f32>>,
    /// Personalized layer mixing weights `(client, lambda)` for this
    /// group, in active order (pFedLA-style policies only; empty
    /// otherwise).  A client applies `x = lambda*u + (1-lambda)*x`
    /// instead of adopting `u` outright.  Appended to both wire
    /// representations (end of the monolithic body / end of the `Begin`
    /// body), keeping the schema append-only.
    pub mix: Vec<(usize, f32)>,
}

impl SyncDecision {
    /// A plain (non-personalized) decision — every client adopts the
    /// aggregate outright.
    pub fn plain(k: usize, group: usize, new_interval: usize, new_params: Vec<Vec<f32>>) -> Self {
        SyncDecision { k, group, new_interval, new_params, mix: Vec::new() }
    }

    /// The mixing weight for `client`, if this decision personalizes it.
    pub fn mix_for(&self, client: usize) -> Option<f32> {
        self.mix.iter().find(|(c, _)| *c == client).map(|&(_, w)| w)
    }
}

/// Participant -> coordinator: one owned client's server-side-algorithm
/// state at a round boundary.  For SCAFFOLD the tensors are the client's
/// refreshed control variate `c_i^+`; for FedNova they are the client's
/// raw round delta `x_i - x_start` (computed client-side in f32, so the
/// value is transport-invariant) with `steps` carrying its local step
/// count `a_i`.
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoState {
    pub k: usize,
    pub client: usize,
    /// Local steps the client took this round (FedNova's a_i; SCAFFOLD
    /// sends the count used to derive the refresh scale, informational).
    pub steps: u64,
    /// One dense tensor per model tensor, in manifest `params` order.
    pub tensors: Vec<Vec<f32>>,
}

/// Coordinator -> participants: refreshed shared server-algorithm state
/// (SCAFFOLD's server control `s_t` after folding the round's per-client
/// refreshes).  Participants replace their local replica wholesale.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlUpdate {
    pub k: usize,
    /// One dense tensor per model tensor, in manifest `params` order.
    pub tensors: Vec<Vec<f32>>,
}

/// Participant -> coordinator: the participant cannot continue (failed to
/// build its model/shard from the wire config, local fault).  Carries the
/// human-readable reason so `serve` can report *why* a joiner vanished
/// instead of a bare join-window expiry.  Added as kind 9 while the wire
/// version was still 1 — the frame layout never changed, older builds
/// reject the unknown kind cleanly.
#[derive(Debug, Clone, PartialEq)]
pub struct Abort {
    pub worker_id: usize,
    pub reason: String,
}

/// Every protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello(Hello),
    Configure(Configure),
    Heartbeat(Heartbeat),
    Assignment(RoundAssignment),
    Update(LayerUpdate),
    Done(BlockDone),
    Decision(SyncDecision),
    Shutdown,
    Abort(Abort),
    Algo(AlgoState),
    Control(ControlUpdate),
}

const KIND_HELLO: u8 = 1;
const KIND_CONFIGURE: u8 = 2;
const KIND_HEARTBEAT: u8 = 3;
const KIND_ASSIGNMENT: u8 = 4;
const KIND_UPDATE: u8 = 5;
const KIND_DONE: u8 = 6;
const KIND_DECISION: u8 = 7;
const KIND_SHUTDOWN: u8 = 8;
const KIND_ABORT: u8 = 9;
// streamed per-layer framing (wire v2): a Begin frame announcing the
// tensor count, then one frame per tensor carrying its 0-based sequence
// number.  Kinds 5/7 above remain the monolithic compatibility shim.
const KIND_UPDATE_BEGIN: u8 = 10;
const KIND_UPDATE_TENSOR: u8 = 11;
const KIND_DECISION_BEGIN: u8 = 12;
const KIND_DECISION_TENSOR: u8 = 13;
// server-side-algorithm state over the wire (SCAFFOLD/FedNova): monolithic
// kinds 14/15 plus the streamed Begin/Tensor split, like Update/Decision
const KIND_ALGO: u8 = 14;
const KIND_CONTROL: u8 = 15;
const KIND_ALGO_BEGIN: u8 = 16;
const KIND_ALGO_TENSOR: u8 = 17;
const KIND_CONTROL_BEGIN: u8 = 18;
const KIND_CONTROL_TENSOR: u8 = 19;

/// Sanity cap on per-message tensor counts (resnet20 has ~80; a corrupt
/// count must not drive a huge allocation).
const MAX_TENSORS: usize = 4096;

impl Message {
    pub fn kind(&self) -> u8 {
        match self {
            Message::Hello(_) => KIND_HELLO,
            Message::Configure(_) => KIND_CONFIGURE,
            Message::Heartbeat(_) => KIND_HEARTBEAT,
            Message::Assignment(_) => KIND_ASSIGNMENT,
            Message::Update(_) => KIND_UPDATE,
            Message::Done(_) => KIND_DONE,
            Message::Decision(_) => KIND_DECISION,
            Message::Shutdown => KIND_SHUTDOWN,
            Message::Abort(_) => KIND_ABORT,
            Message::Algo(_) => KIND_ALGO,
            Message::Control(_) => KIND_CONTROL,
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            Message::Hello(_) => "Hello",
            Message::Configure(_) => "Configure",
            Message::Heartbeat(_) => "Heartbeat",
            Message::Assignment(_) => "RoundAssignment",
            Message::Update(_) => "LayerUpdate",
            Message::Done(_) => "BlockDone",
            Message::Decision(_) => "SyncDecision",
            Message::Shutdown => "Shutdown",
            Message::Abort(_) => "Abort",
            Message::Algo(_) => "AlgoState",
            Message::Control(_) => "ControlUpdate",
        }
    }

    /// Encode to a complete wire frame.  Errors if any sequence overflows
    /// its u32 length prefix or the body exceeds the frame cap.
    pub fn to_frame(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        match self {
            Message::Hello(h) => {
                e.u8(h.version);
                e.usize(h.worker_id);
                e.usize(h.shard_len);
            }
            Message::Configure(c) => {
                e.usize(c.worker_id);
                e.usize(c.n_workers);
                e.usizes(&c.shard)?;
                encode_cfg(&mut e, &c.cfg)?;
            }
            Message::Heartbeat(h) => e.u64(h.nonce),
            Message::Assignment(a) => {
                e.usize(a.k);
                e.usize(a.round);
                e.usize(a.gap);
                e.f32(a.lr);
                e.bool(a.new_round);
                e.usizes(&a.active)?;
                e.usizes(&a.due_groups)?;
            }
            Message::Update(u) => {
                e.usize(u.k);
                e.usize(u.group);
                e.usize(u.client);
                e.u32(u.tensors.len() as u32);
                for p in &u.tensors {
                    p.encode(&mut e)?;
                }
            }
            Message::Done(d) => {
                e.usize(d.worker_id);
                e.usize(d.k);
                e.u32(d.losses.len() as u32);
                for &(c, l) in &d.losses {
                    e.usize(c);
                    e.f64(l);
                }
                e.f64(d.compute_secs);
            }
            Message::Decision(d) => {
                e.usize(d.k);
                e.usize(d.group);
                e.usize(d.new_interval);
                e.u32(d.new_params.len() as u32);
                for t in &d.new_params {
                    e.f32s(t)?;
                }
                encode_mix(&mut e, &d.mix);
            }
            Message::Shutdown => {}
            Message::Abort(a) => {
                e.usize(a.worker_id);
                e.str(&a.reason)?;
            }
            Message::Algo(a) => {
                e.usize(a.k);
                e.usize(a.client);
                e.u64(a.steps);
                e.u32(a.tensors.len() as u32);
                for t in &a.tensors {
                    e.f32s(t)?;
                }
            }
            Message::Control(c) => {
                e.usize(c.k);
                e.u32(c.tensors.len() as u32);
                for t in &c.tensors {
                    e.f32s(t)?;
                }
            }
        }
        wire::frame(self.kind(), &e.buf)
    }

    /// Decode from a frame body with the given kind tag.
    pub fn from_body(kind: u8, body: &[u8]) -> Result<Message> {
        let mut d = Dec::new(body);
        let msg = match kind {
            KIND_HELLO => Message::Hello(Hello {
                version: d.u8()?,
                worker_id: d.usize()?,
                shard_len: d.usize()?,
            }),
            KIND_CONFIGURE => Message::Configure(Configure {
                worker_id: d.usize()?,
                n_workers: d.usize()?,
                shard: d.usizes()?,
                cfg: decode_cfg(&mut d)?,
            }),
            KIND_HEARTBEAT => Message::Heartbeat(Heartbeat { nonce: d.u64()? }),
            KIND_ASSIGNMENT => Message::Assignment(RoundAssignment {
                k: d.usize()?,
                round: d.usize()?,
                gap: d.usize()?,
                lr: d.f32()?,
                new_round: d.bool()?,
                active: d.usizes()?,
                due_groups: d.usizes()?,
            }),
            KIND_UPDATE => {
                let k = d.usize()?;
                let group = d.usize()?;
                let client = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                let tensors =
                    (0..nt).map(|_| Payload::decode_wire(&mut d)).collect::<Result<_>>()?;
                Message::Update(LayerUpdate { k, group, client, tensors })
            }
            KIND_DONE => {
                let worker_id = d.usize()?;
                let k = d.usize()?;
                let nl = d.u32()? as usize;
                let losses = (0..nl)
                    .map(|_| -> Result<(usize, f64)> { Ok((d.usize()?, d.f64()?)) })
                    .collect::<Result<Vec<_>>>()?;
                let compute_secs = d.f64()?;
                Message::Done(BlockDone { worker_id, k, losses, compute_secs })
            }
            KIND_DECISION => {
                let k = d.usize()?;
                let group = d.usize()?;
                let new_interval = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                let new_params = (0..nt).map(|_| d.f32s()).collect::<Result<_>>()?;
                let mix = decode_mix(&mut d)?;
                Message::Decision(SyncDecision { k, group, new_interval, new_params, mix })
            }
            KIND_SHUTDOWN => Message::Shutdown,
            KIND_ABORT => Message::Abort(Abort { worker_id: d.usize()?, reason: d.str()? }),
            KIND_ALGO => {
                let k = d.usize()?;
                let client = d.usize()?;
                let steps = d.u64()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                let tensors = (0..nt).map(|_| d.f32s()).collect::<Result<_>>()?;
                Message::Algo(AlgoState { k, client, steps, tensors })
            }
            KIND_CONTROL => {
                let k = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                let tensors = (0..nt).map(|_| d.f32s()).collect::<Result<_>>()?;
                Message::Control(ControlUpdate { k, tensors })
            }
            t => bail!("unknown message kind {t}"),
        };
        d.finish()?;
        Ok(msg)
    }

    /// Decode one message from the head of a byte buffer; returns
    /// (message, bytes consumed).
    pub fn decode(buf: &[u8]) -> Result<(Message, usize)> {
        let (kind, body, used) = wire::deframe(buf)?;
        Ok((Message::from_body(kind, body)?, used))
    }

    /// Write this message as one frame (no flush).
    pub fn write_to<W: std::io::Write>(&self, w: &mut W) -> Result<()> {
        use anyhow::Context;
        w.write_all(&self.to_frame()?).with_context(|| format!("sending {}", self.kind_name()))
    }

    /// Read one message from a stream.
    pub fn read_from<R: std::io::Read>(r: &mut R) -> Result<Message> {
        let (kind, body) = wire::read_frame(r)?;
        Message::from_body(kind, &body)
    }

    /// Write this message in the streamed per-layer representation (no
    /// flush).  `Update` and `Decision` go out as a `Begin` frame plus one
    /// frame per tensor — the tensor frames through the scatter-gather
    /// writer, so tensor storage is borrowed, never copied into a frame
    /// buffer, and the CRC is computed incrementally as the slices are
    /// written.  Every other kind is a single frame, identical to
    /// [`Message::write_to`].
    pub fn write_streamed<W: std::io::Write>(&self, w: &mut W) -> Result<()> {
        use anyhow::Context;
        match self {
            Message::Update(u) => {
                ensure!(
                    u.tensors.len() <= MAX_TENSORS,
                    "LayerUpdate tensor count {} exceeds cap {MAX_TENSORS}",
                    u.tensors.len()
                );
                let mut e = Enc::new();
                e.usize(u.k);
                e.usize(u.group);
                e.usize(u.client);
                e.u32(u.tensors.len() as u32);
                wire::write_frame(w, KIND_UPDATE_BEGIN, &e.buf)
                    .context("sending UpdateBegin")?;
                for (seq, p) in u.tensors.iter().enumerate() {
                    let mut g = Gather::new();
                    g.u32(seq as u32);
                    p.encode_gather(&mut g)?;
                    wire::write_frame_gather(w, KIND_UPDATE_TENSOR, &g)
                        .with_context(|| format!("sending UpdateTensor {seq}"))?;
                }
                Ok(())
            }
            Message::Decision(d) => {
                let mut scratch = Vec::new();
                for idx in 0..decision_frame_count(d) {
                    // encode_decision_frame writes the tensor frames
                    // gather-style straight into `scratch`; reused across
                    // frames, so staging stays one frame deep
                    encode_decision_frame(d, idx, &mut scratch)?;
                    w.write_all(&scratch).context("sending streamed SyncDecision")?;
                }
                Ok(())
            }
            Message::Algo(a) => {
                ensure!(
                    a.tensors.len() <= MAX_TENSORS,
                    "AlgoState tensor count {} exceeds cap {MAX_TENSORS}",
                    a.tensors.len()
                );
                let mut e = Enc::new();
                e.usize(a.k);
                e.usize(a.client);
                e.u64(a.steps);
                e.u32(a.tensors.len() as u32);
                wire::write_frame(w, KIND_ALGO_BEGIN, &e.buf).context("sending AlgoBegin")?;
                for (seq, t) in a.tensors.iter().enumerate() {
                    let mut g = Gather::new();
                    g.u32(seq as u32);
                    g.f32s(t)?;
                    wire::write_frame_gather(w, KIND_ALGO_TENSOR, &g)
                        .with_context(|| format!("sending AlgoTensor {seq}"))?;
                }
                Ok(())
            }
            Message::Control(c) => {
                let mut scratch = Vec::new();
                for idx in 0..control_frame_count(c) {
                    encode_control_frame(c, idx, &mut scratch)?;
                    w.write_all(&scratch).context("sending streamed ControlUpdate")?;
                }
                Ok(())
            }
            other => other.write_to(w),
        }
    }

    /// Read one *logical* message from a blocking stream, reassembling
    /// streamed per-layer sequences.  The assembler is caller-owned so a
    /// partial update survives across calls on the same connection —
    /// interleaved `Heartbeat` frames return immediately without
    /// disturbing it.
    pub fn read_streamed<R: std::io::Read>(r: &mut R, asm: &mut Assembler) -> Result<Message> {
        loop {
            let (kind, body) = wire::read_frame(r)?;
            if let Some(m) = asm.accept(kind, &body)? {
                return Ok(m);
            }
        }
    }
}

/// Frames in the streamed representation of a `SyncDecision`: one
/// `DecisionBegin` plus one `DecisionTensor` per group tensor.
pub fn decision_frame_count(d: &SyncDecision) -> usize {
    1 + d.new_params.len()
}

/// Encode frame `idx` (0 = `DecisionBegin`, `i+1` = tensor `i`) of `d`'s
/// streamed representation into `out` (cleared first).
///
/// Broadcast paths fan decisions out frame-at-a-time: each frame is
/// encoded once here and written to every live peer before the next is
/// built, so a decision broadcast stages at most one *layer* frame at a
/// time — never the whole decision, let alone the whole model — while
/// per-peer FIFO order is preserved.
pub fn encode_decision_frame(d: &SyncDecision, idx: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    if idx == 0 {
        ensure!(
            d.new_params.len() <= MAX_TENSORS,
            "SyncDecision tensor count {} exceeds cap {MAX_TENSORS}",
            d.new_params.len()
        );
        let mut e = Enc::new();
        e.usize(d.k);
        e.usize(d.group);
        e.usize(d.new_interval);
        e.u32(d.new_params.len() as u32);
        encode_mix(&mut e, &d.mix);
        wire::write_frame(out, KIND_DECISION_BEGIN, &e.buf)
    } else {
        let seq = idx - 1;
        let mut g = Gather::new();
        g.u32(seq as u32);
        g.f32s(&d.new_params[seq])?;
        wire::write_frame_gather(out, KIND_DECISION_TENSOR, &g)
    }
}

/// Frames in the streamed representation of a [`ControlUpdate`].
pub fn control_frame_count(c: &ControlUpdate) -> usize {
    1 + c.tensors.len()
}

/// Encode frame `idx` (0 = `ControlBegin`, `i+1` = tensor `i`) of `c`'s
/// streamed representation into `out` (cleared first) — the control-state
/// twin of [`encode_decision_frame`] for frame-at-a-time fan-out.
pub fn encode_control_frame(c: &ControlUpdate, idx: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    if idx == 0 {
        ensure!(
            c.tensors.len() <= MAX_TENSORS,
            "ControlUpdate tensor count {} exceeds cap {MAX_TENSORS}",
            c.tensors.len()
        );
        let mut e = Enc::new();
        e.usize(c.k);
        e.u32(c.tensors.len() as u32);
        wire::write_frame(out, KIND_CONTROL_BEGIN, &e.buf)
    } else {
        let seq = idx - 1;
        let mut g = Gather::new();
        g.u32(seq as u32);
        g.f32s(&c.tensors[seq])?;
        wire::write_frame_gather(out, KIND_CONTROL_TENSOR, &g)
    }
}

/// Personalized mixing weights, appended to both decision encodings.
fn encode_mix(e: &mut Enc, mix: &[(usize, f32)]) {
    e.u32(mix.len() as u32);
    for &(c, w) in mix {
        e.usize(c);
        e.f32(w);
    }
}

fn decode_mix(d: &mut Dec<'_>) -> Result<Vec<(usize, f32)>> {
    let n = d.u32()? as usize;
    ensure!(n <= 1 << 24, "implausible mix entry count {n}");
    (0..n).map(|_| -> Result<(usize, f32)> { Ok((d.usize()?, d.f32()?)) }).collect()
}

/// Frames [`Message::write_streamed`] emits for `m`.
pub fn streamed_frame_count(m: &Message) -> usize {
    match m {
        Message::Update(u) => 1 + u.tensors.len(),
        Message::Decision(d) => decision_frame_count(d),
        Message::Algo(a) => 1 + a.tensors.len(),
        Message::Control(c) => control_frame_count(c),
        _ => 1,
    }
}

/// Peak *owned staging* bytes any single frame of `m`'s streamed encoding
/// needs: full frame size for `Begin`/non-bulk frames (they go through the
/// copying path), but only `Gather::staging_bytes` + header + CRC for
/// tensor frames, whose payload storage is borrowed.  This is the
/// transport bench's streamed peak-staging metric.
pub fn streamed_staging_bytes(m: &Message) -> Result<usize> {
    const FRAMING: usize = wire::HEADER_LEN + 4; // header + trailing crc
    match m {
        Message::Update(u) => {
            // Begin body: k + group + client (u64 each) + count (u32)
            let mut peak = FRAMING + 8 + 8 + 8 + 4;
            for (seq, p) in u.tensors.iter().enumerate() {
                let mut g = Gather::new();
                g.u32(seq as u32);
                p.encode_gather(&mut g)?;
                peak = peak.max(FRAMING + g.staging_bytes());
            }
            Ok(peak)
        }
        Message::Decision(d) => {
            // Begin body: k/group/interval + count + mix (count + 12B each)
            let mut peak = FRAMING + 8 + 8 + 8 + 4 + 4 + 12 * d.mix.len();
            for (seq, t) in d.new_params.iter().enumerate() {
                let mut g = Gather::new();
                g.u32(seq as u32);
                g.f32s(t)?;
                peak = peak.max(FRAMING + g.staging_bytes());
            }
            Ok(peak)
        }
        Message::Algo(a) => {
            // Begin body: k + client + steps (u64 each) + count (u32)
            let mut peak = FRAMING + 8 + 8 + 8 + 4;
            for (seq, t) in a.tensors.iter().enumerate() {
                let mut g = Gather::new();
                g.u32(seq as u32);
                g.f32s(t)?;
                peak = peak.max(FRAMING + g.staging_bytes());
            }
            Ok(peak)
        }
        Message::Control(c) => {
            let mut peak = FRAMING + 8 + 4;
            for (seq, t) in c.tensors.iter().enumerate() {
                let mut g = Gather::new();
                g.u32(seq as u32);
                g.f32s(t)?;
                peak = peak.max(FRAMING + g.staging_bytes());
            }
            Ok(peak)
        }
        other => Ok(other.to_frame()?.len()),
    }
}

// ---------------------------------------------------------------------------
// Streamed reassembly
// ---------------------------------------------------------------------------

/// Reassembles streamed per-layer frame sequences into whole [`Message`]s.
///
/// One assembler per connection: feed every decoded `(kind, body)` frame
/// to [`Assembler::accept`], which returns `Some(message)` when a frame
/// completes a message.  Monolithic kinds decode as themselves (the
/// compatibility shim), `Heartbeat` passes through even mid-assembly, and
/// protocol violations — a tensor frame without its `Begin`, an
/// out-of-order sequence number, any other kind interleaved into an open
/// sequence — are errors, which the transports treat like any other
/// corrupt traffic on that connection: the peer departs, nobody else's
/// stream is touched.
#[derive(Default)]
pub struct Assembler {
    upd: Option<(LayerUpdate, usize)>,
    dec: Option<(SyncDecision, usize)>,
    algo: Option<(AlgoState, usize)>,
    ctl: Option<(ControlUpdate, usize)>,
}

impl Assembler {
    pub fn new() -> Assembler {
        Assembler::default()
    }

    /// No streamed sequence is currently open.
    pub fn idle(&self) -> bool {
        self.upd.is_none() && self.dec.is_none() && self.algo.is_none() && self.ctl.is_none()
    }

    /// Feed one frame; returns a message when one completes.
    pub fn accept(&mut self, kind: u8, body: &[u8]) -> Result<Option<Message>> {
        match kind {
            KIND_UPDATE_BEGIN => {
                ensure!(self.idle(), "UpdateBegin while another streamed message is open");
                let mut d = Dec::new(body);
                let k = d.usize()?;
                let group = d.usize()?;
                let client = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                d.finish()?;
                let u = LayerUpdate { k, group, client, tensors: Vec::with_capacity(nt) };
                if nt == 0 {
                    return Ok(Some(Message::Update(u)));
                }
                self.upd = Some((u, nt));
                Ok(None)
            }
            KIND_UPDATE_TENSOR => {
                let Some((u, nt)) = self.upd.as_mut() else {
                    bail!("UpdateTensor without an open UpdateBegin")
                };
                let mut d = Dec::new(body);
                let seq = d.u32()? as usize;
                ensure!(
                    seq == u.tensors.len(),
                    "UpdateTensor out of order: seq {seq}, expected {}",
                    u.tensors.len()
                );
                u.tensors.push(Payload::decode_wire(&mut d)?);
                d.finish()?;
                if u.tensors.len() == *nt {
                    let (u, _) = self.upd.take().expect("just matched");
                    return Ok(Some(Message::Update(u)));
                }
                Ok(None)
            }
            KIND_DECISION_BEGIN => {
                ensure!(self.idle(), "DecisionBegin while another streamed message is open");
                let mut d = Dec::new(body);
                let k = d.usize()?;
                let group = d.usize()?;
                let new_interval = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                let mix = decode_mix(&mut d)?;
                d.finish()?;
                let dec = SyncDecision {
                    k,
                    group,
                    new_interval,
                    new_params: Vec::with_capacity(nt),
                    mix,
                };
                if nt == 0 {
                    return Ok(Some(Message::Decision(dec)));
                }
                self.dec = Some((dec, nt));
                Ok(None)
            }
            KIND_DECISION_TENSOR => {
                let Some((dc, nt)) = self.dec.as_mut() else {
                    bail!("DecisionTensor without an open DecisionBegin")
                };
                let mut d = Dec::new(body);
                let seq = d.u32()? as usize;
                ensure!(
                    seq == dc.new_params.len(),
                    "DecisionTensor out of order: seq {seq}, expected {}",
                    dc.new_params.len()
                );
                dc.new_params.push(d.f32s()?);
                d.finish()?;
                if dc.new_params.len() == *nt {
                    let (dc, _) = self.dec.take().expect("just matched");
                    return Ok(Some(Message::Decision(dc)));
                }
                Ok(None)
            }
            KIND_ALGO_BEGIN => {
                ensure!(self.idle(), "AlgoBegin while another streamed message is open");
                let mut d = Dec::new(body);
                let k = d.usize()?;
                let client = d.usize()?;
                let steps = d.u64()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                d.finish()?;
                let a = AlgoState { k, client, steps, tensors: Vec::with_capacity(nt) };
                if nt == 0 {
                    return Ok(Some(Message::Algo(a)));
                }
                self.algo = Some((a, nt));
                Ok(None)
            }
            KIND_ALGO_TENSOR => {
                let Some((a, nt)) = self.algo.as_mut() else {
                    bail!("AlgoTensor without an open AlgoBegin")
                };
                let mut d = Dec::new(body);
                let seq = d.u32()? as usize;
                ensure!(
                    seq == a.tensors.len(),
                    "AlgoTensor out of order: seq {seq}, expected {}",
                    a.tensors.len()
                );
                a.tensors.push(d.f32s()?);
                d.finish()?;
                if a.tensors.len() == *nt {
                    let (a, _) = self.algo.take().expect("just matched");
                    return Ok(Some(Message::Algo(a)));
                }
                Ok(None)
            }
            KIND_CONTROL_BEGIN => {
                ensure!(self.idle(), "ControlBegin while another streamed message is open");
                let mut d = Dec::new(body);
                let k = d.usize()?;
                let nt = d.u32()? as usize;
                ensure!(nt <= MAX_TENSORS, "implausible tensor count {nt}");
                d.finish()?;
                let c = ControlUpdate { k, tensors: Vec::with_capacity(nt) };
                if nt == 0 {
                    return Ok(Some(Message::Control(c)));
                }
                self.ctl = Some((c, nt));
                Ok(None)
            }
            KIND_CONTROL_TENSOR => {
                let Some((c, nt)) = self.ctl.as_mut() else {
                    bail!("ControlTensor without an open ControlBegin")
                };
                let mut d = Dec::new(body);
                let seq = d.u32()? as usize;
                ensure!(
                    seq == c.tensors.len(),
                    "ControlTensor out of order: seq {seq}, expected {}",
                    c.tensors.len()
                );
                c.tensors.push(d.f32s()?);
                d.finish()?;
                if c.tensors.len() == *nt {
                    let (c, _) = self.ctl.take().expect("just matched");
                    return Ok(Some(Message::Control(c)));
                }
                Ok(None)
            }
            // liveness must never wait behind a large streamed message
            KIND_HEARTBEAT => Ok(Some(Message::from_body(kind, body)?)),
            _ => {
                ensure!(
                    self.idle(),
                    "frame kind {kind} interleaved into an open streamed message"
                );
                Ok(Some(Message::from_body(kind, body)?))
            }
        }
    }
}

/// [`StreamDecoder`] + [`Assembler`]: the non-blocking receive path.
/// Socket transports feed raw read chunks via [`MessageStream::extend`]
/// and poll whole logical messages — exactly the old `poll_message`
/// contract, now spanning streamed per-layer sequences.
#[derive(Default)]
pub struct MessageStream {
    dec: StreamDecoder,
    asm: Assembler,
}

impl MessageStream {
    pub fn new() -> MessageStream {
        MessageStream::default()
    }

    /// Append freshly read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.dec.extend(bytes);
    }

    /// Bytes buffered but not yet consumed by a decoded frame.
    pub fn pending(&self) -> usize {
        self.dec.pending()
    }

    /// Try to pop one complete logical message.  `Ok(None)` = need more
    /// bytes (possibly mid-sequence); `Err` = corruption or a streamed
    /// protocol violation on this connection.
    pub fn poll(&mut self) -> Result<Option<Message>> {
        while let Some((kind, body)) = self.dec.poll()? {
            if let Some(m) = self.asm.accept(kind, &body)? {
                return Ok(Some(m));
            }
        }
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// RunConfig wire schema (the worker-relevant subset)
// ---------------------------------------------------------------------------

fn encode_cfg(e: &mut Enc, cfg: &RunConfig) -> Result<()> {
    e.str(&cfg.model)?;
    e.str(cfg.dataset.name())?;
    match cfg.algorithm {
        Algorithm::Sgd => {
            e.u8(0);
            e.f32(0.0);
        }
        Algorithm::Prox { mu } => {
            e.u8(1);
            e.f32(mu);
        }
        Algorithm::Scaffold => {
            e.u8(2);
            e.f32(0.0);
        }
        Algorithm::Nova => {
            e.u8(3);
            e.f32(0.0);
        }
    }
    // policy schema: tag + two usize operands + bool, with an f64 extra
    // operand appended for tags >= 2 (per-tag layout is safe: unknown tags
    // bail, and the wire version gates mixed builds)
    match &cfg.policy {
        Policy::FullSync { interval } => {
            e.u8(0);
            e.usize(*interval);
            e.usize(0);
            e.bool(false);
        }
        Policy::FedLama { tau, phi, accelerate } => {
            e.u8(1);
            e.usize(*tau);
            e.usize(*phi);
            e.bool(*accelerate);
        }
        Policy::DivergenceFeedback { tau, phi, threshold } => {
            e.u8(2);
            e.usize(*tau);
            e.usize(*phi);
            e.bool(false);
            e.f64(*threshold);
        }
        Policy::Personalized { interval, eta } => {
            e.u8(3);
            e.usize(*interval);
            e.usize(0);
            e.bool(false);
            e.f64(*eta);
        }
    }
    match cfg.partition {
        PartitionKind::Iid => {
            e.u8(0);
            e.f64(0.0);
        }
        PartitionKind::Dirichlet { alpha } => {
            e.u8(1);
            e.f64(alpha);
        }
        PartitionKind::Writers => {
            e.u8(2);
            e.f64(0.0);
        }
        PartitionKind::SingleClass => {
            e.u8(3);
            e.f64(0.0);
        }
        PartitionKind::PowerLaw { exponent } => {
            e.u8(4);
            e.f64(exponent);
        }
    }
    e.usize(cfg.n_clients);
    e.f64(cfg.active_ratio);
    e.usize(cfg.samples);
    e.f32(cfg.lr);
    e.usize(cfg.warmup_rounds);
    e.usize(cfg.iterations);
    e.u64(cfg.seed);
    e.usize(cfg.threads);
    e.bool(cfg.use_chunk);
    e.bool(cfg.hetero_local_steps);
    e.str(&cfg.compressor)?;
    // appended for checkpoint/resume: blocks already completed before this
    // run started, so participants fast-forward their client rng streams
    e.usize(cfg.resume_blocks);
    // appended for robustness: the robust-aggregation spec and the fault
    // plan — workers parse the plan to decide whether *they* are the
    // adversary, so both must ride the Configure frame
    e.str(&cfg.aggregator)?;
    e.str(&cfg.chaos)?;
    Ok(())
}

/// The wire bytes of a config, with `resume_blocks` forced to zero: a
/// resumed run carries a different resume offset but must still match the
/// checkpoint's fingerprint, so the offset is excluded from it.  (The
/// coordinator-only `workers` count is excluded by the wire schema itself
/// and is checkpointed separately.)
pub fn cfg_wire_bytes(cfg: &RunConfig) -> Result<Vec<u8>> {
    let mut flat = cfg.clone();
    flat.resume_blocks = 0;
    let mut e = Enc::new();
    encode_cfg(&mut e, &flat)?;
    Ok(e.buf)
}

fn decode_cfg(d: &mut Dec<'_>) -> Result<RunConfig> {
    let model = d.str()?;
    let dataset_name = d.str()?;
    let dataset = DatasetKind::parse(&dataset_name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_name:?} on the wire"))?;
    let algo_tag = d.u8()?;
    let mu = d.f32()?;
    let algorithm = match algo_tag {
        0 => Algorithm::Sgd,
        1 => Algorithm::Prox { mu },
        2 => Algorithm::Scaffold,
        3 => Algorithm::Nova,
        t => bail!("unknown algorithm tag {t}"),
    };
    let pol_tag = d.u8()?;
    let (a, b, acc) = (d.usize()?, d.usize()?, d.bool()?);
    let policy = match pol_tag {
        0 => Policy::FullSync { interval: a },
        1 => Policy::FedLama { tau: a, phi: b, accelerate: acc },
        2 => Policy::DivergenceFeedback { tau: a, phi: b, threshold: d.f64()? },
        3 => Policy::Personalized { interval: a, eta: d.f64()? },
        t => bail!("unknown policy tag {t}"),
    };
    let part_tag = d.u8()?;
    let alpha = d.f64()?;
    let partition = match part_tag {
        0 => PartitionKind::Iid,
        1 => PartitionKind::Dirichlet { alpha },
        2 => PartitionKind::Writers,
        3 => PartitionKind::SingleClass,
        4 => PartitionKind::PowerLaw { exponent: alpha },
        t => bail!("unknown partition tag {t}"),
    };
    Ok(RunConfig {
        engine: EngineKind::Native,
        workers: 0,
        model,
        dataset,
        algorithm,
        policy,
        partition,
        n_clients: d.usize()?,
        active_ratio: d.f64()?,
        samples: d.usize()?,
        lr: d.f32()?,
        warmup_rounds: d.usize()?,
        iterations: d.usize()?,
        seed: d.u64()?,
        threads: d.usize()?,
        use_chunk: d.bool()?,
        hetero_local_steps: d.bool()?,
        compressor: d.str()?,
        resume_blocks: d.usize()?,
        aggregator: d.str()?,
        chaos: d.str()?,
        ..RunConfig::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn qbits_payload_is_exact_reencoding_of_quantizer_output() {
        for bits in [1u32, 4, 8, 16] {
            let mut data = randvec(3000, 42 + bits as u64);
            let mut q = Quantizer::new(bits, 7);
            let nominal = q.compress(&mut data);
            let p = Payload::qbits_from(&data, bits, q.chunk);
            let decoded = p.decode().unwrap();
            for (i, (&a, &b)) in data.iter().zip(&decoded).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} idx={i}: {a} vs {b}");
            }
            assert_eq!(p.nominal_bytes(), nominal, "nominal accounting drifted (bits={bits})");
            assert_eq!(p.nominal_bytes(), q.encoded_bytes(3000));
        }
    }

    #[test]
    fn qbits_zero_and_negative_zero_round_trip() {
        // quantizer maps -x toward -0.0 for tiny x; the sign bit must survive
        let data = vec![0.0f32, -0.0, 1.0, -1.0, 0.5, -0.5, 0.0, 0.0, 0.0];
        let mut lossy = data.clone();
        let mut q = Quantizer::new(4, 3);
        q.compress(&mut lossy);
        let p = Payload::qbits_from(&lossy, 4, q.chunk);
        let decoded = p.decode().unwrap();
        for (&a, &b) in lossy.iter().zip(&decoded) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn topk_payload_round_trips_and_keeps_nominal() {
        let mut data = randvec(500, 5);
        let mut t = TopK::new(0.05);
        let nominal = t.compress(&mut data);
        let p = Payload::topk_from(&data, nominal);
        assert_eq!(p.decode().unwrap(), data);
        assert_eq!(p.nominal_bytes(), nominal);
    }

    #[test]
    fn dense_nominal_matches_ledger_unit() {
        let p = Payload::Dense(vec![0.0; 128]);
        assert_eq!(p.nominal_bytes(), 512);
        assert_eq!(p.len(), 128);
    }

    #[test]
    fn update_stream_seed_separates_messages() {
        let mut seen = std::collections::BTreeSet::new();
        for k in [6usize, 12, 18] {
            for g in 0..4 {
                for c in 0..8 {
                    seen.insert(update_stream_seed(1, k, g, c));
                }
            }
        }
        assert_eq!(seen.len(), 3 * 4 * 8, "stream seeds must be distinct");
        // and deterministic
        assert_eq!(update_stream_seed(9, 6, 1, 2), update_stream_seed(9, 6, 1, 2));
    }

    #[test]
    fn config_survives_the_wire() {
        let cfg = RunConfig {
            model: "femnist_cnn".into(),
            dataset: DatasetKind::Femnist,
            algorithm: Algorithm::Prox { mu: 0.05 },
            policy: Policy::fedlama(10, 4),
            partition: PartitionKind::Dirichlet { alpha: 0.3 },
            n_clients: 24,
            active_ratio: 0.25,
            samples: 128,
            lr: 0.06,
            warmup_rounds: 3,
            iterations: 240,
            seed: 99,
            threads: 4,
            use_chunk: false,
            hetero_local_steps: true,
            compressor: "q8".into(),
            resume_blocks: 17,
            aggregator: "normclip:2+trimmed:1".into(),
            chaos: "signflip:1@r2".into(),
            ..RunConfig::default()
        };
        let msg = Message::Configure(Configure {
            worker_id: 1,
            n_workers: 3,
            shard: vec![1, 4, 7],
            cfg: cfg.clone(),
        });
        let (decoded, used) = Message::decode(&msg.to_frame().unwrap()).unwrap();
        assert_eq!(used, msg.to_frame().unwrap().len());
        let Message::Configure(c) = decoded else { panic!("wrong kind") };
        assert_eq!(c.worker_id, 1);
        assert_eq!(c.n_workers, 3);
        assert_eq!(c.shard, vec![1, 4, 7]);
        // the worker-relevant subset matches field by field
        assert_eq!(c.cfg.model, cfg.model);
        assert_eq!(c.cfg.dataset, cfg.dataset);
        assert_eq!(c.cfg.algorithm, cfg.algorithm);
        assert_eq!(c.cfg.policy, cfg.policy);
        assert_eq!(c.cfg.partition, cfg.partition);
        assert_eq!(c.cfg.n_clients, cfg.n_clients);
        assert_eq!(c.cfg.active_ratio, cfg.active_ratio);
        assert_eq!(c.cfg.samples, cfg.samples);
        assert_eq!(c.cfg.lr, cfg.lr);
        assert_eq!(c.cfg.warmup_rounds, cfg.warmup_rounds);
        assert_eq!(c.cfg.iterations, cfg.iterations);
        assert_eq!(c.cfg.seed, cfg.seed);
        assert_eq!(c.cfg.threads, cfg.threads);
        assert_eq!(c.cfg.use_chunk, cfg.use_chunk);
        assert_eq!(c.cfg.hetero_local_steps, cfg.hetero_local_steps);
        assert_eq!(c.cfg.compressor, cfg.compressor);
        assert_eq!(c.cfg.resume_blocks, cfg.resume_blocks);
        assert_eq!(c.cfg.aggregator, cfg.aggregator);
        assert_eq!(c.cfg.chaos, cfg.chaos);
    }

    fn sample_update() -> LayerUpdate {
        let mut lossy = randvec(300, 11);
        let mut q = Quantizer::new(8, 5);
        q.compress(&mut lossy);
        LayerUpdate {
            k: 12,
            group: 3,
            client: 7,
            tensors: vec![
                Payload::Dense(randvec(257, 1)),
                Payload::qbits_from(&lossy, 8, q.chunk),
                Payload::topk_from(&[0.0, 3.5, 0.0, -1.25], 16),
            ],
        }
    }

    #[test]
    fn streamed_update_round_trips_through_the_assembler() {
        let u = sample_update();
        let mut bytes = Vec::new();
        Message::Update(u.clone()).write_streamed(&mut bytes).unwrap();
        let mut cur = std::io::Cursor::new(&bytes);
        let mut asm = Assembler::new();
        let got = Message::read_streamed(&mut cur, &mut asm).unwrap();
        assert_eq!(got, Message::Update(u));
        assert!(asm.idle());
        assert_eq!(cur.position() as usize, bytes.len(), "no trailing frames");
    }

    #[test]
    fn streamed_decision_round_trips_and_matches_frame_helpers() {
        let d = SyncDecision {
            k: 6,
            group: 1,
            new_interval: 12,
            new_params: vec![randvec(100, 2), randvec(3, 3), Vec::new()],
            mix: vec![(0, 0.25), (7, 1.0)],
        };
        let mut via_stream = Vec::new();
        Message::Decision(d.clone()).write_streamed(&mut via_stream).unwrap();
        // the broadcast helpers emit the exact same byte sequence
        let mut via_frames = Vec::new();
        let mut scratch = Vec::new();
        for idx in 0..decision_frame_count(&d) {
            encode_decision_frame(&d, idx, &mut scratch).unwrap();
            via_frames.extend_from_slice(&scratch);
        }
        assert_eq!(via_stream, via_frames);
        let mut cur = std::io::Cursor::new(&via_stream);
        let mut asm = Assembler::new();
        let got = Message::read_streamed(&mut cur, &mut asm).unwrap();
        assert_eq!(got, Message::Decision(d));
    }

    #[test]
    fn streamed_and_monolithic_decode_to_the_same_message() {
        let u = sample_update();
        let mut stream = MessageStream::new();
        // monolithic kind 5 (the v1 shim), then the streamed sequence,
        // with a heartbeat interleaved mid-assembly
        stream.extend(&Message::Update(u.clone()).to_frame().unwrap());
        let mut streamed = Vec::new();
        Message::Update(u.clone()).write_streamed(&mut streamed).unwrap();
        // splice a heartbeat between the Begin frame and the tensors
        let (kind, body, begin_len) = wire::deframe(&streamed).unwrap();
        assert_eq!(kind, KIND_UPDATE_BEGIN);
        assert!(!body.is_empty());
        stream.extend(&streamed[..begin_len]);
        stream.extend(&Message::Heartbeat(Heartbeat { nonce: 99 }).to_frame().unwrap());
        stream.extend(&streamed[begin_len..]);
        assert_eq!(stream.poll().unwrap(), Some(Message::Update(u.clone())));
        assert_eq!(
            stream.poll().unwrap(),
            Some(Message::Heartbeat(Heartbeat { nonce: 99 })),
            "liveness passes through mid-assembly"
        );
        assert_eq!(stream.poll().unwrap(), Some(Message::Update(u)));
        assert_eq!(stream.poll().unwrap(), None);
    }

    #[test]
    fn assembler_rejects_protocol_violations() {
        let u = sample_update();
        let mut streamed = Vec::new();
        Message::Update(u.clone()).write_streamed(&mut streamed).unwrap();
        let (_, begin_body, begin_len) = wire::deframe(&streamed).unwrap();
        let (_, t0_body, _) = wire::deframe(&streamed[begin_len..]).unwrap();

        // tensor without its Begin
        let mut asm = Assembler::new();
        assert!(asm.accept(KIND_UPDATE_TENSOR, t0_body).is_err());

        // Begin while a sequence is open
        let mut asm = Assembler::new();
        assert!(asm.accept(KIND_UPDATE_BEGIN, begin_body).unwrap().is_none());
        assert!(asm.accept(KIND_UPDATE_BEGIN, begin_body).is_err());

        // out-of-order sequence number (tensor 0 delivered twice)
        let mut asm = Assembler::new();
        assert!(asm.accept(KIND_UPDATE_BEGIN, begin_body).unwrap().is_none());
        assert!(asm.accept(KIND_UPDATE_TENSOR, t0_body).unwrap().is_none());
        let err = format!("{:#}", asm.accept(KIND_UPDATE_TENSOR, t0_body).unwrap_err());
        assert!(err.contains("out of order"), "{err}");

        // a non-heartbeat kind interleaved into an open sequence
        let mut asm = Assembler::new();
        assert!(asm.accept(KIND_UPDATE_BEGIN, begin_body).unwrap().is_none());
        assert!(asm.accept(KIND_SHUTDOWN, &[]).is_err());
    }

    #[test]
    fn streamed_staging_is_bounded_by_one_layer_not_the_message() {
        let u = sample_update();
        let msg = Message::Update(u);
        let mono = msg.to_frame().unwrap().len();
        let peak = streamed_staging_bytes(&msg).unwrap();
        assert!(peak < mono, "streamed staging {peak} must undercut monolithic {mono}");
        let n_frames = streamed_frame_count(&msg);
        assert_eq!(n_frames, 4, "Begin + 3 tensors");
    }

    #[test]
    fn algo_state_round_trips_monolithic_and_streamed() {
        let a = AlgoState {
            k: 24,
            client: 13,
            steps: 7,
            tensors: vec![randvec(257, 21), randvec(3, 22), Vec::new()],
        };
        let msg = Message::Algo(a.clone());
        assert_eq!(msg.kind(), KIND_ALGO);
        let frame = msg.to_frame().unwrap();
        let (decoded, used) = Message::decode(&frame).unwrap();
        assert_eq!(used, frame.len());
        assert_eq!(decoded, msg);
        // streamed path: Begin + one frame per tensor, reassembled exactly
        let mut bytes = Vec::new();
        msg.write_streamed(&mut bytes).unwrap();
        assert_eq!(streamed_frame_count(&msg), 4, "Begin + 3 tensors");
        let mut cur = std::io::Cursor::new(&bytes);
        let mut asm = Assembler::new();
        let got = Message::read_streamed(&mut cur, &mut asm).unwrap();
        assert_eq!(got, msg);
        assert!(asm.idle());
        assert_eq!(cur.position() as usize, bytes.len(), "no trailing frames");
        // raw f32 bit patterns survive: algorithm state is never compressed
        let Message::Algo(back) = got else { panic!("wrong kind") };
        for (ta, tb) in a.tensors.iter().zip(&back.tensors) {
            for (&x, &y) in ta.iter().zip(tb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn control_update_round_trips_and_matches_frame_helpers() {
        let c = ControlUpdate { k: 12, tensors: vec![randvec(64, 31), randvec(9, 32)] };
        let msg = Message::Control(c.clone());
        assert_eq!(msg.kind(), KIND_CONTROL);
        let frame = msg.to_frame().unwrap();
        let (decoded, used) = Message::decode(&frame).unwrap();
        assert_eq!(used, frame.len());
        assert_eq!(decoded, msg);
        // the broadcast helpers emit the exact same byte sequence as the
        // streamed writer (same contract as decision frames)
        let mut via_stream = Vec::new();
        msg.write_streamed(&mut via_stream).unwrap();
        let mut via_frames = Vec::new();
        let mut scratch = Vec::new();
        for idx in 0..control_frame_count(&c) {
            encode_control_frame(&c, idx, &mut scratch).unwrap();
            via_frames.extend_from_slice(&scratch);
        }
        assert_eq!(via_stream, via_frames);
        let mut cur = std::io::Cursor::new(&via_stream);
        let mut asm = Assembler::new();
        assert_eq!(Message::read_streamed(&mut cur, &mut asm).unwrap(), msg);
        assert!(asm.idle());
    }

    #[test]
    fn decision_mix_weights_survive_both_wire_paths() {
        let d = SyncDecision {
            k: 18,
            group: 0,
            new_interval: 6,
            new_params: vec![randvec(40, 41)],
            mix: vec![(2, 0.75), (5, 0.125), (11, 1.0)],
        };
        assert_eq!(d.mix_for(5), Some(0.125));
        assert_eq!(d.mix_for(3), None);
        let msg = Message::Decision(d.clone());
        let frame = msg.to_frame().unwrap();
        let (decoded, _) = Message::decode(&frame).unwrap();
        assert_eq!(decoded, msg, "monolithic");
        let mut bytes = Vec::new();
        msg.write_streamed(&mut bytes).unwrap();
        let mut cur = std::io::Cursor::new(&bytes);
        let mut asm = Assembler::new();
        assert_eq!(Message::read_streamed(&mut cur, &mut asm).unwrap(), msg, "streamed");
        // a plain decision has no mix entries for any client
        let p = SyncDecision::plain(6, 1, 12, vec![randvec(4, 42)]);
        assert!(p.mix.is_empty());
        assert_eq!(p.mix_for(0), None);
    }

    #[test]
    fn new_policy_and_partition_tags_survive_the_wire() {
        for (policy, partition) in [
            (
                Policy::divergence_feedback(10, 4, 0.025),
                PartitionKind::SingleClass,
            ),
            (
                Policy::personalized(8, 0.5),
                PartitionKind::PowerLaw { exponent: 1.6 },
            ),
        ] {
            let cfg = RunConfig {
                policy: policy.clone(),
                partition,
                ..RunConfig::default()
            };
            let msg = Message::Configure(Configure {
                worker_id: 0,
                n_workers: 2,
                shard: vec![0, 2],
                cfg,
            });
            let (decoded, used) = Message::decode(&msg.to_frame().unwrap()).unwrap();
            assert_eq!(used, msg.to_frame().unwrap().len());
            let Message::Configure(c) = decoded else { panic!("wrong kind") };
            assert_eq!(c.cfg.policy, policy);
            assert_eq!(c.cfg.partition, partition);
        }
    }

    #[test]
    fn abort_round_trips_with_reason() {
        let msg = Message::Abort(Abort {
            worker_id: 2,
            reason: "worker received invalid config: unknown model \"nope\"".into(),
        });
        assert_eq!(msg.kind(), 9, "Abort keeps its historical kind tag");
        let frame = msg.to_frame().unwrap();
        let (decoded, used) = Message::decode(&frame).unwrap();
        assert_eq!(used, frame.len());
        let Message::Abort(a) = decoded else { panic!("wrong kind") };
        assert_eq!(a.worker_id, 2);
        assert!(a.reason.contains("unknown model"), "{}", a.reason);
    }
}
