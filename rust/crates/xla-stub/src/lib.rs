//! Compile-time stub for the `xla` crate (xla_extension 0.5.1 PJRT
//! bindings).
//!
//! The offline build environment cannot vendor the real native bindings, so
//! this stub mirrors the API surface `fedlama`'s PJRT engine uses and lets
//! `--features pjrt` type-check.  Every entry point fails at *runtime* with
//! a clear error (`PjRtClient::cpu()` is the first call on any path, so
//! nothing downstream ever executes).  Deployments with the real crate
//! replace this path dependency via `[patch]` — see rust/DESIGN.md,
//! "Execution paths".

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: built against the in-tree xla stub; vendor the real \
         xla_extension bindings (see rust/DESIGN.md) to use the pjrt engine"
            .to_string(),
    ))
}

/// Scalar element types the engine constructs literals from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable()
    }
    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable()
    }
    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable()
    }
}

/// Argument adapter so `execute::<Literal>` and `execute::<&Literal>` both
/// type-check, as with the real crate.
pub trait AsLiteral {}
impl AsLiteral for Literal {}
impl AsLiteral for &Literal {}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsLiteral>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_loudly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
