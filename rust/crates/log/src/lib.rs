//! Minimal vendored stand-in for the `log` crate facade.
//!
//! No logger registry: `trace!`/`debug!` type-check their format args and
//! discard them; `info!`/`warn!`/`error!` print to stderr with a level
//! prefix.  Enough for an offline build with no registry access.

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {{
        let _ = format_args!($($arg)*);
    }};
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {{
        let _ = format_args!($($arg)*);
    }};
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        eprintln!("[info] {}", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        eprintln!("[warn] {}", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        eprintln!("[error] {}", format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand() {
        trace!("t {}", 1);
        debug!("d {}", 2);
        info!("i {}", 3);
        warn!("w {}", 4);
        error!("e {}", 5);
    }
}
