//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this workspace ships the
//! subset of anyhow's API the codebase uses: `Error`, `Result`, the
//! `Context` extension trait for `Result` and `Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros.  Context is stored as a flat chain of strings;
//! `{}` shows the outermost context, `{:#}` joins the whole chain with
//! `": "` like anyhow's alternate formatting.

use std::fmt;

/// A string-chain error: `chain[0]` is the outermost context, the last
/// entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow: `Error` deliberately does NOT implement std::error::Error,
// which is what makes this blanket impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("missing file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");

        // context on an already-anyhow error composes
        let e2: Result<()> = Err(anyhow!("inner {}", 7));
        let e2 = e2.context("outer").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer: inner 7");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(5).unwrap_err()).contains("five"));
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }
}
