//! Quickstart: FedLAMA vs FedAvg on the toy MLP workload, in seconds.
//! Runs on the hermetic native backend — no artifacts needed.
//!
//!   cargo run --release --example quickstart
//!
//! Trains the same federated workload three ways — FedAvg with the short
//! interval tau'=6 (accuracy anchor), FedAvg with the long interval 24
//! (communication anchor), and FedLAMA(6,4) — and prints the paper's
//! headline trade-off: FedLAMA keeps the short-interval accuracy at close
//! to the long-interval communication cost.

use fedlama::aggregation::Policy;
use fedlama::config::RunConfig;
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::reports;

fn main() -> anyhow::Result<()> {
    let base = RunConfig {
        model_dir: "artifacts/mlp".into(),
        dataset: DatasetKind::Toy,
        n_clients: 8,
        partition: fedlama::config::PartitionKind::Dirichlet { alpha: 0.3 },
        samples: 256,
        lr: 0.08,
        warmup_rounds: 2,
        iterations: 240,
        eval_every_rounds: 0,
        eval_examples: 1024,
        seed: 42,
        ..Default::default()
    };

    let mut results = Vec::new();
    for (label, policy) in [
        ("FedAvg(6)", Policy::fedavg(6)),
        ("FedAvg(24)", Policy::fedavg(24)),
        ("FedLAMA(6,4)", Policy::fedlama(6, 4)),
    ] {
        let cfg = RunConfig { policy, ..base.clone() };
        let mut coord = Coordinator::new(cfg)?;
        let m = coord.run()?;
        println!("{}", reports::summary_line(label, &m));
        results.push(m);
    }

    println!();
    println!("{}", reports::tradeoff_note(&results[0], &results[1], &results[2]));
    println!(
        "\n(The paper's claim, Table 1: FedLAMA matches FedAvg(tau') accuracy at a \
         communication cost close to FedAvg(phi*tau').)"
    );
    Ok(())
}
