//! Communication-efficiency analysis (paper Figures 2 and 3 + Eq. 9).
//!
//! Runs FedAvg(6) and FedLAMA(6,2) on the non-IID ResNet20 workload and
//! prints per-layer sync counts (Figure 2) and per-layer Eq. 9 data sizes
//! (Figure 3), showing where FedLAMA's savings come from: the output-side
//! large layers are synchronized less often.
//!
//!   cargo run --release --example comm_analysis

use fedlama::aggregation::Policy;
use fedlama::config::{PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::tables::Table;
use fedlama::reports;

fn main() -> anyhow::Result<()> {
    let mk = |policy| RunConfig {
        model_dir: "artifacts/resnet20".into(),
        dataset: DatasetKind::Cifar10,
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        policy,
        n_clients: 4,
        samples: 128,
        lr: 0.4,
        warmup_rounds: 2,
        iterations: 120,
        eval_every_rounds: 0,
        eval_examples: 512,
        seed: 5,
        ..Default::default()
    };
    let mut avg = Coordinator::new(mk(Policy::fedavg(6)))?;
    let m_avg = avg.run()?;
    let mut lama = Coordinator::new(mk(Policy::fedlama(6, 2)))?;
    let m_lama = lama.run()?;

    let mut t = Table::new(
        "Figures 2+3: per-layer communications and Eq.9 cost (non-IID CIFAR-10)",
        &["layer", "dim", "FedAvg syncs", "FedLAMA syncs", "FedAvg cost", "FedLAMA cost"],
    );
    for (a, l) in m_avg.per_group.iter().zip(&m_lama.per_group) {
        t.row(vec![
            a.0.clone(),
            a.1.to_string(),
            a.2.to_string(),
            l.2.to_string(),
            a.3.to_string(),
            l.3.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total Eq.9 cost: FedAvg {} vs FedLAMA {} ({:.1}%)",
        m_avg.total_comm_cost,
        m_lama.total_comm_cost,
        100.0 * m_lama.total_comm_cost as f64 / m_avg.total_comm_cost as f64
    );

    // the paper's headline mechanism: savings concentrate on large layers
    let largest = m_avg.per_group.iter().map(|g| g.1).max().unwrap();
    let (avg_syncs, lama_syncs) = m_avg
        .per_group
        .iter()
        .zip(&m_lama.per_group)
        .find(|(a, _)| a.1 == largest)
        .map(|(a, l)| (a.2, l.2))
        .unwrap();
    println!(
        "largest layer ({largest} params): {avg_syncs} syncs under FedAvg vs {lama_syncs} under FedLAMA"
    );

    reports::write_report(
        std::path::Path::new("reports/comm_analysis.csv"),
        &reports::figure23_csv(&[("fedavg6", &m_avg), ("fedlama6_2", &m_lama)]),
    )?;
    println!("wrote reports/comm_analysis.csv");
    Ok(())
}
