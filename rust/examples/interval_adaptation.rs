//! Interval-adaptation dynamics (paper Figure 1 + Algorithm 2 in action).
//!
//! Runs FedLAMA on the ResNet20/CIFAR-10 workload and shows, for every
//! adjustment round, which layers were relaxed to phi*tau' and the
//! delta_l / 1-lambda_l crossover the decision came from.
//!
//!   cargo run --release --example interval_adaptation

use fedlama::aggregation::Policy;
use fedlama::config::{PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::reports;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        model_dir: "artifacts/resnet20".into(),
        dataset: DatasetKind::Cifar10,
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        policy: Policy::fedlama(6, 2),
        n_clients: 4,
        samples: 128,
        lr: 0.4,
        warmup_rounds: 0,
        iterations: 60,
        eval_every_rounds: 0,
        eval_examples: 512,
        seed: 11,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg)?;
    let metrics = coord.run()?;

    let groups = coord.manifest().groups.clone();
    println!("=== Algorithm 2 adjustments over training ===");
    for (i, adj) in coord.schedule().adjustments.iter().enumerate() {
        let relaxed: Vec<&str> = (0..groups.len())
            .filter(|&g| adj.intervals[g] > 6)
            .map(|g| groups[g].name.as_str())
            .collect();
        let relaxed_dim: usize =
            (0..groups.len()).filter(|&g| adj.intervals[g] > 6).map(|g| groups[g].dim).sum();
        let total_dim: usize = groups.iter().map(|g| g.dim).sum();
        println!(
            "adjustment {}: {}/{} layers relaxed to phi*tau' ({:.1}% of parameters)",
            i + 1,
            adj.relaxed,
            groups.len(),
            100.0 * relaxed_dim as f64 / total_dim as f64
        );
        if i == 0 {
            println!("  relaxed: {}", relaxed.join(", "));
        }
    }

    if let Some(ascii) = reports::figure1_ascii(&coord, 60, 14) {
        println!("\n{ascii}");
    }
    if let Some(csv) = reports::figure1_csv(&coord) {
        reports::write_report(std::path::Path::new("reports/figure1_example.csv"), &csv)?;
        println!("wrote reports/figure1_example.csv");
    }

    // The paper's Figure-2 observation: the relaxed parameter share should
    // be large (output-side layers dominate), i.e. crossover height << 0.5.
    let adj = coord.schedule().adjustments.first().unwrap();
    let cross = adj
        .delta_curve
        .iter()
        .zip(&adj.comm_curve)
        .position(|(d, c)| d >= c)
        .unwrap_or(adj.delta_curve.len() - 1);
    println!(
        "crossover at sorted-layer {} of {}, height delta = {:.3} (paper: ~0.2, well below 0.5)",
        cross + 1,
        groups.len(),
        adj.delta_curve[cross]
    );
    let _ = metrics;
    Ok(())
}
