//! Baseline comparison: the local-SGD family the paper positions against
//! (FedAvg, FedProx, SCAFFOLD, FedNova) plus FedLAMA, on a non-IID
//! workload with heterogeneous client data sizes.
//!
//!   cargo run --release --example baselines

use fedlama::aggregation::Policy;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::tables::Table;

fn main() -> anyhow::Result<()> {
    let base = RunConfig {
        model_dir: "artifacts/mlp".into(),
        dataset: DatasetKind::Toy,
        partition: PartitionKind::Dirichlet { alpha: 0.2 },
        n_clients: 8,
        samples: 200,
        lr: 0.08,
        warmup_rounds: 2,
        iterations: 240,
        eval_every_rounds: 0,
        eval_examples: 1024,
        seed: 21,
        use_chunk: false,
        ..Default::default()
    };

    let runs: Vec<(&str, Algorithm, Policy, bool)> = vec![
        ("FedAvg(6)", Algorithm::Sgd, Policy::fedavg(6), false),
        ("FedProx(6) mu=0.01", Algorithm::Prox { mu: 0.01 }, Policy::fedavg(6), false),
        ("SCAFFOLD(6)", Algorithm::Scaffold, Policy::fedavg(6), false),
        ("FedNova(6) hetero", Algorithm::Nova, Policy::fedavg(6), true),
        ("FedLAMA(6,2)", Algorithm::Sgd, Policy::fedlama(6, 2), false),
        ("FedLAMA(6,4)", Algorithm::Sgd, Policy::fedlama(6, 4), false),
    ];

    let mut t = Table::new(
        "Local-SGD baselines under non-IID data (Dirichlet 0.2, 8 clients)",
        &["Algorithm", "Validation acc.", "Final loss", "Comm. cost", "Wall (s)"],
    );
    let mut baseline_cost = None;
    for (label, algo, policy, hetero) in runs {
        let cfg = RunConfig {
            algorithm: algo,
            policy,
            hetero_local_steps: hetero,
            ..base.clone()
        };
        let mut coord = Coordinator::new(cfg)?;
        let m = coord.run()?;
        let cost_pct = match baseline_cost {
            None => {
                baseline_cost = Some(m.total_comm_cost);
                100.0
            }
            Some(b) => 100.0 * m.total_comm_cost as f64 / b as f64,
        };
        t.row(vec![
            label.to_string(),
            format!("{:.2}%", 100.0 * m.final_acc),
            format!("{:.4}", m.final_loss),
            format!("{cost_pct:.2}%"),
            format!("{:.1}", m.wall_secs),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Note: the variance-reduction baselines tackle client drift at full\n\
         communication cost; FedLAMA attacks the cost itself.  The paper\n\
         (§2) treats the two directions as composable."
    );
    Ok(())
}
