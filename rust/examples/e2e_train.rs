//! End-to-end driver (EXPERIMENTS.md §E2E): trains the FEMNIST CNN across
//! a federated client fleet with FedLAMA for a few hundred rounds of local
//! SGD on the synthetic writer-heterogeneous corpus, logging the loss
//! curve, then re-runs the FedAvg anchors to report the paper's headline
//! trade-off end-to-end.  Every layer of the stack is exercised: the
//! compute backend (native MLP by default; PJRT/Pallas under `--features
//! pjrt`), chunked local steps, layer-wise aggregation, and the rust
//! coordinator with its parallel client cluster.
//!
//!   cargo run --release --example e2e_train [iters] [clients]

use fedlama::aggregation::Policy;
use fedlama::config::{PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::reports;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let clients: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let base = RunConfig {
        model_dir: "artifacts/femnist_cnn".into(),
        dataset: DatasetKind::Femnist,
        partition: PartitionKind::Writers,
        n_clients: clients,
        active_ratio: 0.5,
        samples: 300,
        lr: 0.06,
        warmup_rounds: 4,
        iterations: iters / 40 * 40, // multiple of phi*tau' = 40
        policy: Policy::fedlama(10, 4),
        eval_every_rounds: 1,
        eval_examples: 1024,
        seed: 3,
        threads: 0, // auto: fan clients across the cluster's worker threads
        verbose: true,
        ..Default::default()
    };

    eprintln!(
        "=== E2E: FEMNIST CNN, {} clients (50% active), {} iterations, FedLAMA(10,4) ===",
        clients,
        base.iterations
    );
    let mut coord = Coordinator::new(base.clone())?;
    let lama = coord.run()?;
    println!("\nloss curve (round, train_loss, val_acc, comm):");
    for p in &lama.curve {
        println!(
            "  round {:>3}  loss {:.4}  acc {}  comm {}",
            p.round,
            p.train_loss,
            p.val_acc.map(|v| format!("{:.2}%", 100.0 * v)).unwrap_or_else(|| "-".into()),
            p.comm_cost
        );
    }
    reports::write_report(std::path::Path::new("reports/e2e_curve.csv"), &lama.curve_csv())?;
    eprintln!("wrote reports/e2e_curve.csv");

    // FedAvg anchors for the trade-off statement
    let mut anchors = Vec::new();
    for (label, policy) in [("FedAvg(10)", Policy::fedavg(10)), ("FedAvg(40)", Policy::fedavg(40))]
    {
        let cfg = RunConfig { policy, verbose: false, ..base.clone() };
        let mut coord = Coordinator::new(cfg)?;
        let m = coord.run()?;
        println!("{}", reports::summary_line(label, &m));
        anchors.push(m);
    }
    println!("{}", reports::summary_line("FedLAMA(10,4)", &lama));
    println!("\n{}", reports::tradeoff_note(&anchors[0], &anchors[1], &lama));

    // sanity for CI use: training must actually have learned something.
    // 62-class task, chance = 1.6%; demand clear signal above chance for
    // short runs and substantial accuracy for the full default run.
    let floor = if iters >= 400 { 0.25 } else { 2.5 / 62.0 };
    anyhow::ensure!(lama.final_acc > floor, "e2e accuracy too low: {}", lama.final_acc);
    let first = lama.curve.first().unwrap().train_loss;
    let last = lama.curve.last().unwrap().train_loss;
    anyhow::ensure!(last < first, "loss did not decrease: {first} -> {last}");
    eprintln!("\nE2E OK");
    Ok(())
}
